"""Multi-host supervisor: spec round-trips, host dispatch, explicit-index
sharding, remaining-task enumeration, the chaos fault matrix (merged
results bit-identical to a clean unsharded run under every fault class),
and supervisor resume after a mid-sweep death."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.dse import run_dse
from repro.core.explore import (remaining_candidate_indices,
                                sweep_fingerprint)
from repro.dist.faults import FAULT_EXIT_CODE, FaultSpec, plan_faults
from repro.dist.hosts import (LocalProcessHost, ShellCommandHost,
                              parse_hosts)
from repro.dist.supervisor import (Supervisor, SupervisorError, SweepSpec,
                                   quick_spec, read_state,
                                   supervised_results)


def _sig(points):
    return [(p.arch, p.objective, p.energy_j, p.delay_s) for p in points]


def _two_hosts():
    return [LocalProcessHost(name="local0", retry_seed=100),
            LocalProcessHost(name="local1", retry_seed=101)]


@pytest.fixture(scope="module")
def spec():
    return quick_spec(seed=3, n_shards=2)


@pytest.fixture(scope="module")
def clean_sig(spec):
    """The failure-free unsharded run every supervised result must match
    bit-for-bit."""
    pts = run_dse(spec.build_candidates(), spec.build_workloads(),
                  spec.build_cfg(), use_sa=True)
    return _sig(pts)


# ---------------------------------------------------------------------------
# SweepSpec
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip(spec):
    again = SweepSpec.from_json(spec.to_json())
    assert again == spec
    assert again.fingerprint() == spec.fingerprint()
    assert len(spec.build_candidates()) == 6
    assert list(spec.build_workloads()) == ["tf"]


def test_spec_validation():
    with pytest.raises(ValueError):
        SweepSpec(workloads={}, grid={"tops": 72.0})
    with pytest.raises(ValueError):
        SweepSpec(workloads={"tf": "tf-quick"}, grid={"tops": 72.0},
                  n_shards=0)
    with pytest.raises(ValueError):
        SweepSpec(workloads={"tf": "tf-quick"}, grid={"tops": 72.0},
                  screen_keep="auto")
    with pytest.raises(ValueError):
        SweepSpec(workloads={"tf": "tf-quick"}, grid={"tops": 72.0},
                  cfg={"sa": {}})


def test_fault_spec_grammar():
    assert FaultSpec.parse("kill") == FaultSpec("kill", 1, 0.0)
    assert FaultSpec.parse("stall:3") == FaultSpec("stall", 3, 0.0)
    assert FaultSpec.parse("slow") == FaultSpec("slow", 1, 0.05)
    s = FaultSpec("corrupt", 2, 0.0)
    assert FaultSpec.parse(s.encode()) == s
    with pytest.raises(ValueError):
        FaultSpec("meteor")


def test_plan_faults_deterministic():
    a = plan_faults(0, 4, "kill")
    assert a == plan_faults(0, 4, "kill")
    (victim,) = a
    assert 0 <= victim < 4
    plans = {tuple(sorted((v, s.k) for v, s in
                          plan_faults(seed, 4, "kill").items()))
             for seed in range(8)}
    assert len(plans) > 1              # the seed actually matters


# ---------------------------------------------------------------------------
# Hosts
# ---------------------------------------------------------------------------

def test_local_process_host_runs_and_logs(tmp_path):
    h = LocalProcessHost()
    log = tmp_path / "out.log"
    handle = h.launch(["-c", "import os; print('env=' + "
                       "os.environ.get('DIST_TEST', ''))"],
                      env={"DIST_TEST": "yes"}, log_path=log)
    assert handle.wait(timeout=30) == 0
    assert "env=yes" in log.read_text()


def test_shell_command_host_loopback(tmp_path):
    """The '{cmd}' template is a local loopback: env prefixes and argv
    quoting must survive the sh -c hop."""
    h = ShellCommandHost("{cmd}", python=sys.executable)
    log = tmp_path / "out.log"
    handle = h.launch(["-c", "import os; print(os.environ['DIST_TEST'])"],
                      env={"DIST_TEST": "a b'c"}, log_path=log)
    assert handle.wait(timeout=30) == 0
    assert "a b'c" in log.read_text()


def test_shell_command_host_requires_cmd_slot():
    with pytest.raises(ValueError, match="cmd"):
        ShellCommandHost("ssh dse-01")


def test_parse_hosts_defaults():
    (h,) = parse_hosts([], 0)
    assert isinstance(h, LocalProcessHost)
    hosts = parse_hosts(["{cmd}"], 2)
    assert len(hosts) == 3
    assert isinstance(hosts[0], ShellCommandHost)


# ---------------------------------------------------------------------------
# Explicit-index sharding + remaining-task enumeration
# ---------------------------------------------------------------------------

def test_indices_run_matches_full_run_slice(spec, clean_sig):
    cands = spec.build_candidates()
    wls = spec.build_workloads()
    cfg = spec.build_cfg()
    pts = run_dse(cands, wls, cfg, use_sa=True, indices=[1, 4],
                  shard_label="sX")
    by_arch = {s[0]: s for s in clean_sig}
    assert sorted(_sig(pts), key=str) == \
        sorted((by_arch[p.arch] for p in pts), key=str)
    assert {p.arch for p in pts} == {cands[1], cands[4]}


def test_indices_validation(spec):
    cands = spec.build_candidates()
    wls = spec.build_workloads()
    cfg = spec.build_cfg()
    with pytest.raises(ValueError, match="stride"):
        run_dse(cands, wls, cfg, indices=[0], shard=(0, 2))
    with pytest.raises(ValueError, match="screen"):
        run_dse(cands, wls, cfg, indices=[0], screen_keep=0.5)
    with pytest.raises(ValueError, match="outside"):
        run_dse(cands, wls, cfg, indices=[99])


def test_remaining_candidate_indices(spec, tmp_path):
    cands = spec.build_candidates()
    wls = spec.build_workloads()
    cfg = spec.build_cfg()
    ckpt = tmp_path / "part.jsonl"
    # no file yet: everything remains
    assert remaining_candidate_indices(cands, wls, cfg, ckpt) == \
        list(range(6))
    run_dse(cands, wls, cfg, use_sa=True, indices=[0, 2, 5],
            checkpoint=ckpt)
    assert remaining_candidate_indices(cands, wls, cfg, ckpt) == [1, 3, 4]
    assert remaining_candidate_indices(cands, wls, cfg, ckpt,
                                       indices=[0, 1, 2]) == [1]
    # a different SA seed invalidates every record (the resume gate)
    cfg2 = quick_spec(seed=4).build_cfg()
    assert remaining_candidate_indices(cands, wls, cfg2, ckpt) == \
        list(range(6))
    with pytest.raises(ValueError, match="outside"):
        remaining_candidate_indices(cands, wls, cfg, ckpt, indices=[77])


def test_sweep_fingerprint_matches_engine(spec, tmp_path):
    wls = spec.build_workloads()
    cfg = spec.build_cfg()
    fp = sweep_fingerprint(wls, cfg)
    ckpt = tmp_path / "c.jsonl"
    run_dse(spec.build_candidates(), wls, cfg, use_sa=True, indices=[0],
            checkpoint=ckpt)
    header = json.loads(ckpt.read_text().splitlines()[0])
    assert header["_config"] == fp


# ---------------------------------------------------------------------------
# Supervisor: happy path, chaos matrix, resume
# ---------------------------------------------------------------------------

def test_supervisor_happy_path_bit_identical(spec, clean_sig, tmp_path):
    sup = Supervisor(spec, out_dir=tmp_path, hosts=_two_hosts(),
                     hb_timeout=60.0, poll_s=0.15)
    merged = sup.run()
    assert _sig(supervised_results(spec, merged)) == clean_sig
    state = read_state(sup.state_path)
    assert state["plan"]["fingerprint"] == spec.fingerprint()
    assert state["merged"] is not None
    evs = [e["ev"] for e in state["events"]]
    assert evs.count("launch") == 2 and "merged" in evs


@pytest.mark.parametrize("kind", ["kill", "corrupt", "dup", "slow",
                                  "stall"])
def test_chaos_matrix_bit_identical(spec, clean_sig, tmp_path, kind):
    """The headline invariant: under every injected fault class the
    supervised sweep's merged result is bit-identical to the clean run."""
    sup = Supervisor(spec, out_dir=tmp_path / kind, hosts=_two_hosts(),
                     hb_timeout=5.0, poll_s=0.15, fault_kind=kind,
                     fault_seed=0)
    merged = sup.run()
    assert _sig(supervised_results(spec, merged)) == clean_sig
    evs = [e["ev"] for e in read_state(sup.state_path)["events"]]
    if kind in ("kill", "corrupt"):
        # the injected crash exits FAULT_EXIT_CODE and must have been
        # retried (or completed post-crash for corrupt)
        rcs = [e["rc"] for e in read_state(sup.state_path)["events"]
               if e["ev"] == "exit"]
        assert FAULT_EXIT_CODE in rcs
    if kind == "stall":
        assert "hb_timeout" in evs and "dead" in evs and "reshard" in evs
    if kind == "dup":
        assert evs.count("launch") >= 3      # the duplicate twin launched


def test_supervisor_resume_after_death(spec, clean_sig, tmp_path):
    """Kill path: one host, one attempt — the victim shard's crash
    exhausts retries, kills the host pool, and the supervisor dies with
    its journal on disk.  A fresh supervisor resumes mid-sweep and
    completes bit-identically."""
    out = tmp_path / "sweep"
    sup = Supervisor(spec, out_dir=out,
                     hosts=[LocalProcessHost(name="only")],
                     hb_timeout=60.0, poll_s=0.15, max_attempts=1,
                     fault_kind="kill", fault_seed=0)
    with pytest.raises(SupervisorError):
        sup.run()
    state = read_state(sup.state_path)
    assert state["merged"] is None
    assert any(e["ev"] == "dead" for e in state["events"])
    sup2 = Supervisor(spec, out_dir=out, hosts=_two_hosts(),
                      hb_timeout=60.0, poll_s=0.15)
    merged = sup2.resume()
    assert _sig(supervised_results(spec, merged)) == clean_sig
    resumed = read_state(sup2.state_path)
    assert any(e["ev"] == "resume" for e in resumed["events"])


def test_supervisor_resume_on_foreign_journal(tmp_path, spec):
    other = quick_spec(seed=99)
    sup = Supervisor(other, out_dir=tmp_path, hosts=_two_hosts())
    sup._event("plan", fingerprint="dse:v2:something-else", keep=[0],
               n_candidates=1, shards=[[0]], spec=other.to_dict())
    sup2 = Supervisor(spec, out_dir=tmp_path, hosts=_two_hosts())
    with pytest.raises(SupervisorError, match="different sweep"):
        sup2.resume()


def test_supervisor_screen_once_matches_sharded_screen(tmp_path):
    """screen_keep < 1: the supervisor screens once and ships the keep
    set; results must match the clean run that screens internally."""
    spec = quick_spec(seed=3, n_shards=2, screen_keep=0.5)
    clean = _sig(run_dse(spec.build_candidates(), spec.build_workloads(),
                         spec.build_cfg(), use_sa=True, screen_keep=0.5))
    sup = Supervisor(spec, out_dir=tmp_path, hosts=_two_hosts(),
                     poll_s=0.15)
    merged = sup.run()
    assert _sig(supervised_results(spec, merged)) == clean
    # only the keep set was dispatched
    plan = read_state(sup.state_path)["plan"]
    assert len(plan["keep"]) == 3


# ---------------------------------------------------------------------------
# sweep_ctl CLI
# ---------------------------------------------------------------------------

def test_sweep_ctl_launch_status_merge(tmp_path, capsys):
    from repro.launch.sweep_ctl import main
    out = tmp_path / "run"
    rc = main(["launch", "--quick", "--out", str(out), "--hosts", "2",
               "--poll", "0.15", "--fault", "kill", "--fault-seed", "0",
               "--verify-clean"])
    assert rc == 0
    assert "bit-identical" in capsys.readouterr().out
    assert main(["status", "--out", str(out)]) == 0
    s = capsys.readouterr().out
    assert "fingerprint" in s and "shard progress" in s
    assert main(["merge", "--out", str(out),
                 "--on-conflict", "error"]) == 0
    assert "complete" in capsys.readouterr().out
