"""Property-based tests (hypothesis) for the system's invariants."""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.encoding import (LMS, MS, factor_parts, parse_regions,
                                 random_lms, split_points)
from repro.core.workload import Graph, Layer, LayerGroup

SET = settings(max_examples=40, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# encoding invariants
# ---------------------------------------------------------------------------

@SET
@given(dim=st.integers(1, 512), parts=st.integers(1, 64))
def test_split_points_properties(dim, parts):
    if parts > dim:
        with pytest.raises(ValueError):
            split_points(dim, parts)
        return
    sp = split_points(dim, parts)
    sizes = np.diff(sp)
    assert sp[0] == 0 and sp[-1] == dim
    assert (sizes >= 1).all()
    assert sizes.max() - sizes.min() <= 1


@SET
@given(n=st.integers(1, 64),
       dims=st.tuples(st.integers(1, 32), st.integers(1, 32),
                      st.integers(1, 8), st.integers(1, 64)),
       seed=st.integers(0, 2**31 - 1))
def test_factor_parts_product_and_caps(n, dims, seed):
    rng = np.random.default_rng(seed)
    try:
        part = factor_parts(n, dims, rng)
    except ValueError:
        # must genuinely be infeasible for any single-dim fallback
        assert all(d < n for d in dims)
        return
    assert int(np.prod(part)) == n
    for p, d in zip(part, dims):
        assert 1 <= p <= d


@SET
@given(h=st.integers(1, 16), w=st.integers(1, 16), b=st.integers(1, 4),
       k=st.integers(1, 16), seed=st.integers(0, 1000))
def test_regions_partition_exactly(h, w, b, k, seed):
    """Correspondence Rule regions tile the ofmap cube with no gap/overlap."""
    lyr = Layer(name="x", kind="conv", K=k * 2, H=h * 2, W=w * 2, C=3)
    rng = np.random.default_rng(seed)
    part = factor_parts(min(h * w * b * k, 8),
                        (lyr.H, lyr.W, b * 2, lyr.K), rng)
    nc = int(np.prod(part))
    ms = MS(part=part, cg=tuple(range(nc)), fd=(0, 0, 0))
    regs = parse_regions(ms, lyr, batch_unit=b * 2)
    total = sum(r.elems for r in regs.values())
    assert total == lyr.H * lyr.W * (b * 2) * lyr.K
    regs_l = list(regs.values())
    for i in range(len(regs_l)):
        for j in range(i + 1, len(regs_l)):
            assert regs_l[i].overlap(regs_l[j]) == 0


def _chain_graph(n_layers: int) -> Graph:
    g = Graph("chain")
    prev = None
    for i in range(n_layers):
        g.add(Layer(name=f"l{i}", kind="conv", K=8, H=8, W=8,
                    C=8 if prev else 3), [prev] if prev else ())
        prev = f"l{i}"
    return g


@SET
@given(n_layers=st.integers(2, 5), n_cores=st.integers(6, 36),
       seed=st.integers(0, 1000))
def test_random_lms_always_valid(n_layers, n_cores, seed):
    g = _chain_graph(n_layers)
    grp = LayerGroup(names=tuple(g.topo_order()), batch_unit=2)
    lms = random_lms(grp, g, n_cores, 2, np.random.default_rng(seed))
    lms.validate(grp, g, n_cores, 2)


@SET
@given(seed=st.integers(0, 500), op_seq=st.lists(st.integers(1, 5),
                                                 min_size=1, max_size=30))
def test_sa_operators_preserve_validity(seed, op_seq):
    """Any operator sequence keeps the LMS valid (paper's closure claim)."""
    from repro.core.hw import ArchConfig
    from repro.core.sa import _Op
    from repro.core.tangram import stripe_lms
    arch = ArchConfig(x_cores=4, y_cores=3, xcut=2, ycut=1)
    g = _chain_graph(3)
    grp = LayerGroup(names=tuple(g.topo_order()), batch_unit=2)
    lms = stripe_lms(grp, g, arch, arch.n_dram)
    lms.validate(grp, g, arch.n_cores, arch.n_dram)
    rng = np.random.default_rng(seed)
    ops = _Op(g, arch, rng)
    idle = [c for c in range(arch.n_cores) if c not in lms.cores_used()]
    for op in op_seq:
        if op == 1:
            cand = ops.op1(grp, lms)
        elif op == 2:
            cand = ops.op2(grp, lms)
        elif op == 3:
            cand = ops.op3(grp, lms)
        elif op == 4:
            r = ops.op4(grp, lms, idle)
            cand = None
            if r is not None:
                cand, idle = r
        else:
            cand = ops.op5(grp, lms)
        if cand is not None:
            cand.validate(grp, g, arch.n_cores, arch.n_dram)
            lms = cand


# ---------------------------------------------------------------------------
# optimizer / compression invariants
# ---------------------------------------------------------------------------

@SET
@given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e3))
def test_int8_error_feedback_bounded(seed, scale):
    from repro.optim.adamw import compress_int8, decompress_int8
    rng = np.random.default_rng(seed)
    g = np.asarray(rng.normal(size=(64,)) * scale, np.float32)
    q, s = compress_int8(g)
    deq = decompress_int8(np.asarray(q), np.asarray(s))
    err = np.abs(np.asarray(deq) - g)
    assert err.max() <= float(s) * 0.5 + 1e-6      # half-ULP of the quantizer


@SET
@given(seed=st.integers(0, 200))
def test_error_feedback_unbiased_over_steps(seed):
    """Accumulated EF-compressed gradients converge to the true sum."""
    from repro.optim.adamw import ef_compress_tree, init_error_state
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    err = init_error_state(g)
    acc = np.zeros(32)
    for _ in range(16):
        q, s, err = ef_compress_tree(g, err)
        acc += np.asarray(q["w"], np.float32) * float(s["w"])
    true = np.asarray(g["w"]) * 16
    # relative error shrinks with steps thanks to error feedback
    assert np.abs(acc - true).max() <= float(s["w"]) * 2


# ---------------------------------------------------------------------------
# data pipeline invariants
# ---------------------------------------------------------------------------

@SET
@given(step=st.integers(0, 10_000), seed=st.integers(0, 1000))
def test_batches_deterministic(step, seed):
    from repro.data.pipeline import DataConfig, make_batch
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4, seed=seed)
    b1 = make_batch(cfg, step)
    b2 = make_batch(cfg, step)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (b1["labels"] == b2["labels"]).all()
    assert b1["tokens"].max() < 1000 and b1["tokens"].min() >= 0


@SET
@given(step=st.integers(0, 100))
def test_host_shards_disjoint_and_cover(step):
    from repro.data.pipeline import DataConfig, make_batch
    full = make_batch(DataConfig(vocab=500, seq_len=16, global_batch=8,
                                 n_hosts=1, host_id=0), step)
    parts = [make_batch(DataConfig(vocab=500, seq_len=16, global_batch=8,
                                   n_hosts=2, host_id=h), step)
             for h in (0, 1)]
    stacked = np.concatenate([p["tokens"] for p in parts], axis=0)
    assert (stacked == full["tokens"]).all()


# ---------------------------------------------------------------------------
# HLO parsing invariants
# ---------------------------------------------------------------------------

@SET
@given(dims=st.lists(st.integers(1, 64), min_size=0, max_size=4),
       dtype=st.sampled_from(["f32", "bf16", "s8", "pred", "u32"]))
def test_shape_bytes_parser(dims, dtype):
    from repro.launch.hlo_analysis import _type_bytes
    sizes = {"f32": 4, "bf16": 2, "s8": 1, "pred": 1, "u32": 4}
    typestr = f"{dtype}[{','.join(map(str, dims))}]{{}}"
    n = int(np.prod(dims)) if dims else 1
    assert _type_bytes(typestr) == n * sizes[dtype]
