"""Exploration engine: parallel determinism, screening, replica exchange,
checkpoint/resume, Pareto frontier, seed derivation, SA history logging."""

import json

import pytest

from repro.core import dse as dse_mod
from repro.core.dse import DSEConfig, grid_candidates, joint_reuse_dse, run_dse
from repro.core.explore import (ExplorationEngine, ResumableSweep,
                                arch_from_dict, arch_to_dict, candidate_key,
                                derive_seed, pareto_frontier,
                                replica_exchange_sa)
from repro.core.graph_partition import partition_graph
from repro.core.hw import simba_arch
from repro.core.sa import SAConfig, sa_optimize
from repro.core.workloads import transformer


def _tf_small():
    return transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")


def _grid(n=8):
    cands = grid_candidates(
        72.0, mac_options=(512, 1024), cut_options=(1, 2),
        dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
        glb_options=(1024, 2048))
    assert len(cands) >= n
    return cands[:n]


def _cfg(iters=60, seed=3, **kw):
    return DSEConfig(batch=8, sa=SAConfig(iters=iters, seed=seed, **kw))


def _sig(points):
    return [(p.arch, p.objective, p.energy_j, p.delay_s) for p in points]


# ---------------------------------------------------------------------------
# Parallel determinism
# ---------------------------------------------------------------------------

def test_run_dse_parallel_bit_identical_to_serial():
    g = _tf_small()
    cands = _grid(6)
    serial = run_dse(cands, {"TF": g}, _cfg())
    par = run_dse(cands, {"TF": g}, _cfg(), n_workers=4)
    assert _sig(serial) == _sig(par)


def test_per_candidate_seeds_stable_under_subsetting():
    """A candidate's result depends on its index, not on which other
    candidates run (what makes screening and resume consistent)."""
    g = _tf_small()
    cands = _grid(4)
    full = run_dse(cands, {"TF": g}, _cfg())
    by_arch = {p.arch: p.objective for p in full}
    with ExplorationEngine({"TF": g}, _cfg()) as eng:
        sub = eng.map_archs(cands[:2])     # indices 0, 1 as in the full run
    for pt in sub:
        assert pt.objective == by_arch[pt.arch]


def test_derive_seed_deterministic_and_distinct():
    assert derive_seed(3, 5) == derive_seed(3, 5)
    seeds = {derive_seed(0, i) for i in range(100)}
    assert len(seeds) == 100
    assert derive_seed(0, 1) != derive_seed(1, 0)


# ---------------------------------------------------------------------------
# Screening
# ---------------------------------------------------------------------------

def test_screening_prunes_and_matches_full_run():
    g = _tf_small()
    cands = _grid(6)
    full = run_dse(cands, {"TF": g}, _cfg())
    by_arch = {p.arch: p.objective for p in full}
    screened = run_dse(cands, {"TF": g}, _cfg(), screen_keep=0.5)
    assert len(screened) == 3
    # survivors' SA results are identical to the exhaustive run's
    for p in screened:
        assert p.objective == by_arch[p.arch]


def test_screen_keep_one_is_exhaustive():
    g = _tf_small()
    cands = _grid(4)
    assert _sig(run_dse(cands, {"TF": g}, _cfg())) == \
        _sig(run_dse(cands, {"TF": g}, _cfg(), screen_keep=1.0))


def test_engine_screen_sorted():
    g = _tf_small()
    with ExplorationEngine({"TF": g}, _cfg()) as eng:
        pts = eng.screen(_grid(5))
    objs = [p.objective for p in pts]
    assert objs == sorted(objs)


# ---------------------------------------------------------------------------
# Replica-exchange SA
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7])
def test_replica_exchange_never_worse_than_single_chain(seed):
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    single = sa_optimize(g, arch, groups, 8, SAConfig(iters=300, seed=seed))
    multi = sa_optimize(g, arch, groups, 8,
                        SAConfig(iters=300, seed=seed, n_chains=4))
    assert multi.cost <= single.cost
    for grp, lms in multi.mapping:
        lms.validate(grp, g, arch.n_cores, arch.n_dram)


def test_replica_exchange_deterministic():
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    cfg = SAConfig(iters=200, seed=5, n_chains=3)
    r1 = replica_exchange_sa(g, arch, groups, 8, cfg)
    r2 = replica_exchange_sa(g, arch, groups, 8, cfg)
    assert r1.cost == r2.cost
    assert r1.proposed == r2.proposed


def test_sa_history_logged_unconditionally():
    """History length depends only on iters/log_every, not on how many
    proposals happened to be applicable."""
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    for seed in (0, 1, 2):
        res = sa_optimize(g, arch, groups, 8,
                          SAConfig(iters=200, seed=seed, log_every=10))
        assert len(res.history) == 20


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------

def test_checkpoint_resume_skips_completed(tmp_path, monkeypatch):
    g = _tf_small()
    cands = _grid(4)
    ck = tmp_path / "sweep.jsonl"
    first = run_dse(cands, {"TF": g}, _cfg(), checkpoint=ck)
    assert ck.exists()

    calls = []
    real = dse_mod.evaluate_task

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(dse_mod, "evaluate_task", counting)
    resumed = run_dse(cands, {"TF": g}, _cfg(), checkpoint=ck)
    assert not calls                       # everything came from the file
    assert [p.objective for p in resumed] == [p.objective for p in first]

    # partial resume: drop the last record, only that task re-runs
    lines = ck.read_text().splitlines()
    ck.write_text("\n".join(lines[:-1]) + "\n")
    resumed2 = run_dse(cands, {"TF": g}, _cfg(), checkpoint=ck)
    assert len(calls) == 1
    assert [p.objective for p in resumed2] == [p.objective for p in first]


def test_checkpoint_config_change_discards(tmp_path):
    g = _tf_small()
    cands = _grid(2)
    ck = tmp_path / "sweep.jsonl"
    run_dse(cands, {"TF": g}, _cfg(iters=40), checkpoint=ck)
    # different SA budget -> stale records must not be reused
    pts = run_dse(cands, {"TF": g}, _cfg(iters=80), checkpoint=ck)
    fresh = run_dse(cands, {"TF": g}, _cfg(iters=80))
    assert [p.objective for p in pts] == [p.objective for p in fresh]


def test_resumable_sweep_tolerates_truncated_line(tmp_path):
    p = tmp_path / "s.jsonl"
    s = ResumableSweep(p, config_fingerprint="fp")
    s.add("a", {"x": 1})
    s.add("b", {"x": 2})
    with p.open("a") as f:
        f.write('{"_key": "c", "x":')       # killed mid-write
    s2 = ResumableSweep(p, config_fingerprint="fp")
    assert "a" in s2 and "b" in s2 and "c" not in s2
    assert s2.get("b") == {"x": 2}
    # last-wins override
    s2.add("a", {"x": 9})
    assert ResumableSweep(p, config_fingerprint="fp").get("a") == {"x": 9}


def test_checkpoint_workload_change_discards(tmp_path):
    """Editing the graph under an unchanged dict key must invalidate the
    checkpoint (fingerprint hashes workload content, not names)."""
    cands = _grid(2)
    ck = tmp_path / "sweep.jsonl"
    run_dse(cands, {"TF": _tf_small()}, _cfg(), checkpoint=ck)
    g2 = transformer(n_layers=3, d_model=128, d_ff=256, seq=64, name="tf-s")
    pts = run_dse(cands, {"TF": g2}, _cfg(), checkpoint=ck)
    fresh = run_dse(cands, {"TF": g2}, _cfg())
    assert [p.objective for p in pts] == [p.objective for p in fresh]


def test_checkpoint_grid_reorder_recomputes_shifted_seeds(tmp_path):
    """Editing the candidate grid shifts indices (and derived seeds);
    resumed records must not be reused under the wrong seed."""
    g = _tf_small()
    cands = _grid(4)
    ck = tmp_path / "sweep.jsonl"
    run_dse(cands, {"TF": g}, _cfg(), checkpoint=ck)
    reordered = list(reversed(cands))
    resumed = run_dse(reordered, {"TF": g}, _cfg(), checkpoint=ck)
    fresh = run_dse(reordered, {"TF": g}, _cfg())
    assert _sig(resumed) == _sig(fresh)


def test_resumable_sweep_discard_keeps_backup(tmp_path):
    p = tmp_path / "s.jsonl"
    s = ResumableSweep(p, config_fingerprint="v1")
    s.add("a", {"x": 1})
    s2 = ResumableSweep(p, config_fingerprint="v2")   # config changed
    assert "a" not in s2
    bak = tmp_path / "s.jsonl.bak"
    assert bak.exists() and '"x": 1' in bak.read_text()
    # a second discard must not clobber the first backup
    s2.add("b", {"x": 2})
    ResumableSweep(p, config_fingerprint="v3")
    assert '"x": 1' in bak.read_text()
    assert '"x": 2' in (tmp_path / "s.jsonl.bak1").read_text()
    # resume=False also sets the old file aside instead of truncating
    ResumableSweep(p, config_fingerprint="v3", resume=False)
    assert (tmp_path / "s.jsonl.bak2").exists()


def test_resumable_sweep_read_only_never_writes(tmp_path):
    p = tmp_path / "s.jsonl"
    s = ResumableSweep(p, config_fingerprint="v1")
    s.add("a", {"x": 1})
    before = p.read_text()
    # read() must not reset on fingerprint mismatch or corruption
    with p.open("a") as f:
        f.write("{broken\n")
        f.write(json.dumps({"_key": "b", "x": 2}) + "\n")
    mid = p.read_text()
    r = ResumableSweep.read(p)
    assert r.get("a") == {"x": 1} and r.get("b") == {"x": 2}
    assert p.read_text() == mid
    assert before in mid


def test_arch_roundtrip_and_key():
    for arch in _grid(4) + [simba_arch()]:
        assert arch_from_dict(json.loads(
            json.dumps(arch_to_dict(arch)))) == arch
    keys = {candidate_key(a) for a in _grid(8)}
    assert len(keys) == 8


def test_arch_from_dict_refuses_unknown_tech():
    d = arch_to_dict(simba_arch())
    d["tech"] = "tsmc5-not-registered"
    with pytest.raises(ValueError, match="unknown tech"):
        arch_from_dict(d)


def test_corrupt_mid_line_discards_all_records(tmp_path):
    """Records parsed before a corrupt non-trailing line must not survive
    the discard — the fresh file would silently omit them on skip/resume."""
    p = tmp_path / "s.jsonl"
    s = ResumableSweep(p, config_fingerprint="fp")
    s.add("a", {"x": 1})
    with p.open("a") as f:
        f.write("{broken\n")
        f.write(json.dumps({"_key": "b", "x": 2}) + "\n")
    s2 = ResumableSweep(p, config_fingerprint="fp")
    assert "a" not in s2 and "b" not in s2 and len(s2) == 0
    assert (tmp_path / "s.jsonl.bak").exists()


def test_missing_header_invalidates_fingerprinted_sweep(tmp_path):
    """If the _config header is lost (killed while writing it), records can
    no longer be proven to match this config and must be discarded."""
    p = tmp_path / "s.jsonl"
    s = ResumableSweep(p, config_fingerprint="fp")
    s.add("a", {"x": 1})
    # strip the header line
    lines = [ln for ln in p.read_text().splitlines() if "_config" not in ln]
    p.write_text("\n".join(lines) + "\n")
    s2 = ResumableSweep(p, config_fingerprint="fp")
    assert "a" not in s2
    # un-fingerprinted sweeps (hillclimb) never require a header
    p2 = tmp_path / "h.jsonl"
    h = ResumableSweep(p2)
    h.add("k", {"ok": True})
    assert ResumableSweep(p2).get("k") == {"ok": True}


# ---------------------------------------------------------------------------
# Pareto frontier
# ---------------------------------------------------------------------------

def test_pareto_frontier_dominance():
    def pt(mc, e, d):
        return dse_mod.DSEPoint(arch=simba_arch(), mc=mc, energy_j=e,
                                delay_s=d, objective=mc * e * d)

    a = pt(1.0, 1.0, 1.0)
    b = pt(2.0, 2.0, 2.0)       # dominated by a
    c = pt(0.5, 3.0, 1.0)       # trades MC for E
    d = pt(1.0, 1.0, 1.0)       # tie with a: both kept
    front = pareto_frontier([a, b, c, d])
    assert b not in front
    assert a in front and c in front and d in front


def test_pareto_frontier_of_real_sweep():
    g = _tf_small()
    pts = run_dse(_grid(6), {"TF": g}, _cfg(), use_sa=False)
    front = pareto_frontier(pts)
    assert 1 <= len(front) <= len(pts)
    assert front[0].objective == pts[0].objective  # best scalar is never dominated


# ---------------------------------------------------------------------------
# Joint reuse DSE through the engine
# ---------------------------------------------------------------------------

def test_joint_reuse_dse_ranks_and_parallelizes():
    g = _tf_small()
    bases = [simba_arch().replace(xcut=1, ycut=1),
             simba_arch().replace(xcut=2, ycut=1)]
    serial = joint_reuse_dse(bases, (1, 4), {"TF": g}, _cfg(iters=40))
    assert len(serial) == 2
    assert serial[0][1] <= serial[1][1]
    par = joint_reuse_dse(bases, (1, 4), {"TF": g}, _cfg(iters=40),
                          n_workers=2)
    assert [(b, p) for b, p in serial] == [(b, p) for b, p in par]


# ---------------------------------------------------------------------------
# Adaptive (gap-rule) screening
# ---------------------------------------------------------------------------

def test_adaptive_screening_prunes_and_is_deterministic(tmp_path):
    g = _tf_small()
    cands = _grid(8)
    ck = tmp_path / "auto.ckpt.jsonl"
    with ExplorationEngine({"TF": g}, _cfg(), checkpoint=ck) as eng:
        pts = eng.run(cands, screen_keep="auto")
        screen = eng.last_screen
    assert 1 <= len(pts) <= len(cands)
    assert screen is not None and len(screen) == len(cands)
    objs = [p.objective for p in pts]
    assert objs == sorted(objs)
    # every kept candidate matches the exhaustive sweep's value for the
    # same index (adaptive mode must not perturb per-task seeds)
    full = {p.arch: p.objective for p in run_dse(cands, {"TF": g}, _cfg())}
    for p in pts:
        assert p.objective == full[p.arch]
    # results sorted best-first (the gap rule is a heuristic — pruned
    # candidates are assumed, not proven, unable to beat the kept best)
    assert pts[0].objective == min(objs)
    # resume from the checkpoint replays identically
    with ExplorationEngine({"TF": g}, _cfg(), checkpoint=ck) as eng:
        again = eng.run(cands, screen_keep="auto")
    assert _sig(pts) == _sig(again)


def test_adaptive_screening_rejects_shards_and_bad_modes():
    g = _tf_small()
    cands = _grid(4)
    with ExplorationEngine({"TF": g}, _cfg()) as eng:
        with pytest.raises(ValueError, match="adaptive screening"):
            eng.run(cands, screen_keep="auto", shard=(0, 2))
        with pytest.raises(ValueError, match="fraction or 'auto'"):
            eng.run(cands, screen_keep="later")
        # single candidate / no SA: 'auto' degrades to exhaustive
        only = eng.run(cands[:1], screen_keep="auto")
        assert len(only) == 1
        tmap = eng.run(cands, use_sa=False, screen_keep="auto")
        assert len(tmap) == len(cands)


# ---------------------------------------------------------------------------
# Replica-exchange swap diagnostics
# ---------------------------------------------------------------------------

def test_replica_exchange_records_swap_acceptance():
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    cfg = SAConfig(iters=200, seed=0, n_chains=4, swap_every=25)
    res = replica_exchange_sa(g, arch, groups, 8, cfg)
    # ladder = chains 1..3 -> 2 adjacent pairs, iters/swap_every attempts
    assert res.swap_attempts == [200 // 25] * 2
    assert all(0 <= a <= t for a, t in
               zip(res.swap_accepts, res.swap_attempts))
    assert len(res.swap_rates()) == 2
    # single chain: no ladder, no stats
    single = sa_optimize(g, arch, groups, 8, SAConfig(iters=50, seed=0))
    assert single.swap_attempts == [] and single.swap_rates() == []


def test_single_chain_checkpoint_survives_re_knob_defaults(tmp_path):
    """The retune moved the (inert for n_chains=1) replica-exchange
    defaults; checkpoints written under the old (50, 3.0) defaults are
    value-identical and must resume, not be discarded."""
    g = _tf_small()
    cands = _grid(3)
    ck = tmp_path / "old.ckpt.jsonl"
    with ExplorationEngine({"TF": g}, _cfg()) as eng:
        pts = eng.run(cands)
        # rewrite the checkpoint as the pre-retune engine would have
        sweep = eng._open_sweep(ck, use_sa=True)
        old_fp = eng._fingerprint(True, re_knobs=(50, 3.0))
        assert old_fp != eng._fingerprint(True)
        lines = [json.dumps({"_config": old_fp})]
        for i, p in enumerate(pts):
            for wl, (e, d) in p.per_workload.items():
                from repro.core.explore import task_checkpoint_key
                from repro.core.explore import derive_task_seed
                ci = cands.index(p.arch)
                lines.append(json.dumps(
                    {"_key": task_checkpoint_key(p.arch, wl),
                     "seed": derive_task_seed(eng.cfg.sa.seed, ci, 0),
                     "workload": wl, "arch": arch_to_dict(p.arch),
                     "energy_j": e, "delay_s": d}))
        ck.write_text("\n".join(lines) + "\n")
    with ExplorationEngine({"TF": g}, _cfg(), checkpoint=ck,
                           progress=True) as eng2:
        resumed = eng2.run(cands)
    assert _sig(resumed) == _sig(pts)
    # the file was migrated in place to the current fingerprint
    head = json.loads(ck.read_text().splitlines()[0])
    with ExplorationEngine({"TF": g}, _cfg()) as eng3:
        assert head["_config"] == eng3._fingerprint(True)
