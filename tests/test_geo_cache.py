"""Bounded geometry cache: _LRU semantics (cap, eviction order, recency
refresh), the REPRO_GEO_CACHE_CAP override, and bit-identical rebuild of
evicted _GEO_CACHE entries."""

import numpy as np
import pytest

from repro.core.analyzer import _GEO_CACHE, _LRU, _geo_cache_cap
from repro.core.evaluator import Evaluator
from repro.core.graph_partition import partition_graph
from repro.core.hw import ArchConfig
from repro.core.tangram import tangram_map
from repro.core.workloads import transformer


def _arch():
    return ArchConfig(x_cores=4, y_cores=3, xcut=2, ycut=1,
                      noc_bw=16.0, d2d_bw=8.0, dram_bw=64.0,
                      glb_kb=512, macs_per_core=256)


def test_lru_caps_and_evicts_oldest():
    lru = _LRU(maxsize=3)
    for k in "abc":
        lru.put(k, k.upper())
    assert len(lru) == 3
    lru.put("d", "D")                       # evicts "a", the oldest
    assert len(lru) == 3
    assert lru.get("a") is None
    assert lru.get("b") == "B"


def test_lru_get_refreshes_recency_near_cap():
    lru = _LRU(maxsize=3)
    for k in "abc":
        lru.put(k, k.upper())
    # at/above half-fill a hit refreshes recency: "a" becomes newest,
    # so the next eviction takes "b"
    assert lru.get("a") == "A"
    lru.put("d", "D")
    assert lru.get("a") == "A"
    assert lru.get("b") is None


def test_lru_below_half_fill_skips_refresh():
    lru = _LRU(maxsize=10)
    lru.put("a", 1)
    lru.put("b", 2)
    assert lru.get("a") == 1                # no reorder below half-fill
    assert list(lru) == ["a", "b"]


def test_lru_put_existing_key_does_not_evict():
    lru = _LRU(maxsize=2)
    lru.put("a", 1)
    lru.put("b", 2)
    lru.put("a", 3)                         # overwrite, not a new entry
    assert len(lru) == 2
    assert lru.get("a") == 3 and lru.get("b") == 2


def test_geo_cache_cap_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_GEO_CACHE_CAP", raising=False)
    assert _geo_cache_cap() == 262_144
    monkeypatch.setenv("REPRO_GEO_CACHE_CAP", "1024")
    assert _geo_cache_cap() == 1024
    monkeypatch.setenv("REPRO_GEO_CACHE_CAP", "0")
    assert _geo_cache_cap() == 262_144      # non-positive -> default
    monkeypatch.setenv("REPRO_GEO_CACHE_CAP", "not-a-number")
    assert _geo_cache_cap() == 262_144


def test_geo_cache_is_bounded_lru():
    assert isinstance(_GEO_CACHE, _LRU)
    assert _GEO_CACHE.maxsize == _geo_cache_cap()


def test_evicted_geometry_rebuilds_bit_identical():
    """Shrink the shared cache so an analysis evicts its own entries,
    then re-run: results must not change (pure geometry, eviction only
    costs recompute time)."""
    arch = _arch()
    g = transformer(n_layers=1, d_model=64, d_ff=128, seq=32, name="tf-geo")
    groups = partition_graph(g, arch, 8)
    init = tangram_map(groups, g, arch)

    def run():
        ev = Evaluator(arch, g)
        rows = ev.eval_requests_batch(list(init), 8)
        return [(ge.delay_s, ge.energy_j, ge.stage_time_s,
                 tuple(an.edge_bytes)) for ge, an in rows]

    baseline = run()
    saved_items = list(_GEO_CACHE.items())
    saved_cap = _GEO_CACHE.maxsize
    try:
        _GEO_CACHE.clear()
        _GEO_CACHE.maxsize = 2              # thrash: constant eviction
        thrashed = run()
        assert len(_GEO_CACHE) <= 2
        _GEO_CACHE.clear()
        _GEO_CACHE.maxsize = saved_cap
        rebuilt = run()
    finally:
        _GEO_CACHE.maxsize = saved_cap
        _GEO_CACHE.clear()
        _GEO_CACHE.update(saved_items)
    assert thrashed == baseline
    assert rebuilt == baseline
