"""Trip-count-aware HLO analysis: exactness on known-FLOPs programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (HloAnalyzer, _type_bytes,
                                       analyze_hlo_text, top_contributors)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_exact():
    L, n, B = 8, 128, 4
    w = jnp.ones((L, n, n), jnp.float32)
    x = jnp.ones((B, n), jnp.float32)

    def f(w, x):
        def body(h, wl):
            return h @ wl, None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    costs = analyze_hlo_text(_compile(f, w, x).as_text())
    assert costs.flops == pytest.approx(2 * B * n * n * L, rel=0.02)


def test_nested_scan_flops_exact():
    L, M, n, B = 4, 3, 64, 2
    w = jnp.ones((L, n, n), jnp.float32)
    x = jnp.ones((M, B, n), jnp.float32)

    def f(w, x):
        def outer(c, xm):
            def body(h, wl):
                return h @ wl, None
            h, _ = jax.lax.scan(body, xm, w)
            return c + h.sum(), None
        s, _ = jax.lax.scan(outer, jnp.zeros(()), x)
        return s

    costs = analyze_hlo_text(_compile(f, w, x).as_text())
    assert costs.flops == pytest.approx(2 * B * n * n * L * M, rel=0.02)


def test_unrolled_equals_scanned():
    n, B, L = 64, 2, 6
    w = jnp.ones((L, n, n), jnp.float32)
    x = jnp.ones((B, n), jnp.float32)

    def scanned(w, x):
        def body(h, wl):
            return h @ wl, None
        return jax.lax.scan(body, x, w)[0].sum()

    def unrolled(w, x):
        h = x
        for i in range(L):
            h = h @ w[i]
        return h.sum()

    cs = analyze_hlo_text(_compile(scanned, w, x).as_text())
    cu = analyze_hlo_text(_compile(unrolled, w, x).as_text())
    assert cs.flops == pytest.approx(cu.flops, rel=0.05)


def test_dus_cache_update_charged_as_slice():
    """KV-cache style dus must NOT be charged the whole buffer."""
    cache = jnp.zeros((64, 1024, 16), jnp.float32)    # 4 MB
    upd = jnp.ones((64, 1, 16), jnp.float32)          # 4 KB

    def f(cache, upd):
        def body(c, i):
            c = jax.lax.dynamic_update_slice(c, upd, (0, i, 0))
            return c, None
        c, _ = jax.lax.scan(body, cache, jnp.arange(8))
        return c.sum()

    costs = analyze_hlo_text(_compile(f, cache, upd).as_text())
    full = 64 * 1024 * 16 * 4
    # 8 slice-updates plus one full reduce; far below 8 x full buffer
    assert costs.bytes < 4 * full


def test_collectives_inside_loops_multiply():
    """psum inside a scan counts once per iteration."""
    import os
    # need >= 2 devices for a real collective: emulate via named sharding?
    # On 1 device XLA folds the psum away, so just assert parsing stability.
    text = """
HloModule m

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4]{0} get-tuple-element(%p), index=1
  %ar = f32[4]{0} all-reduce(%x), to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4]{0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4]{0}) tuple(%z, %x)
  %w = (s32[], f32[4]{0}) while(%t0), condition=%cond, body=%body
  ROOT %o = f32[4]{0} get-tuple-element(%w), index=1
}
"""
    costs = analyze_hlo_text(text)
    assert costs.coll_bytes == pytest.approx(5 * 16)      # 5 trips x 16B
    assert costs.coll_by_kind["all-reduce"] == pytest.approx(80)


def test_type_bytes_tuple():
    assert _type_bytes("(f32[2,3]{1,0}, bf16[4]{0})") == 24 + 8


def test_top_contributors_runs():
    n = 64
    a = jnp.ones((n, n), jnp.float32)

    def f(a):
        return (a @ a).sum()

    top = top_contributors(_compile(f, a).as_text(), "flops", 5)
    assert top and top[0][0] >= 2 * n ** 3 * 0.9
