"""Fused jitted construct->replay->eval pass (``backend="jax"``) and the
jax replay backend: parity envelopes across the workload zoo, bad-backend /
bad-dtype refusal, fused-vs-exact cache separation in CachedEvaluator, and
the rescore-winners contract of ``SAConfig(backend="jax")``."""

import numpy as np
import pytest

from repro.core.analyzer import _jax_replay
from repro.core.encoding import random_lms
from repro.core.evaluator import CachedEvaluator, Evaluator
from repro.core.explore import replica_exchange_sa
from repro.core.graph_partition import partition_graph
from repro.core.hw import ArchConfig
from repro.core.sa import SAConfig
from repro.core.workloads import make_workload

# the documented fused parity envelope (DESIGN.md "Fused jitted pass"):
# float32 math + unordered segment reduction, never bit-identical
REL_TOL = 1e-4

ZOO = ("tf-quick", "moe-quick", "mla-quick")


def _arch():
    return ArchConfig(x_cores=4, y_cores=3, xcut=2, ycut=1,
                      noc_bw=16.0, d2d_bw=8.0, dram_bw=64.0,
                      glb_kb=512, macs_per_core=256)


def _requests(g, arch, seed=0, n=3):
    groups = partition_graph(g, arch, 8)
    rng = np.random.default_rng(seed)
    return [(grp, random_lms(grp, g, arch.n_cores, arch.n_dram, rng))
            for grp in groups for _ in range(n)]


# ---------------------------------------------------------------------------
# fused evaluator pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ZOO)
def test_fused_parity_envelope(spec):
    arch = _arch()
    g = make_workload(spec)
    reqs = _requests(g, arch, seed=1)
    ev = Evaluator(arch, g)
    exact = ev.eval_requests_batch(reqs, 8)
    fused = ev.eval_requests_batch(reqs, 8, backend="jax")
    assert len(fused) == len(exact)
    for (ge, an), (gf, anf) in zip(exact, fused):
        assert anf is None        # fused rows carry no analyses by contract
        assert an is not None
        for a, b in ((ge.delay_s, gf.delay_s),
                     (ge.energy_j, gf.energy_j),
                     (ge.stage_time_s, gf.stage_time_s)):
            assert abs(a - b) / max(abs(a), 1e-30) < REL_TOL
        assert ge.bottleneck == gf.bottleneck
        for k in ge.energy_breakdown:
            a, b = ge.energy_breakdown[k], gf.energy_breakdown[k]
            assert abs(a - b) <= REL_TOL * max(abs(a), 1e-12)


def test_fused_empty_requests():
    arch = _arch()
    ev = Evaluator(arch, make_workload("tf-quick"))
    assert ev.eval_requests_batch([], 8, backend="jax") == []


def test_fused_bad_backend_refused():
    arch = _arch()
    g = make_workload("tf-quick")
    ev = Evaluator(arch, g)
    reqs = _requests(g, arch, n=1)
    with pytest.raises(ValueError, match="unknown eval batch backend"):
        ev.eval_requests_batch(reqs, 8, backend="torch")
    with pytest.raises(ValueError, match="unknown analyze batch backend"):
        ev.analyzer.analyze_requests(reqs, 8, backend="torch")


def test_cached_evaluator_keeps_fused_results_separate():
    """Parity-grade fused values must never satisfy an exact-path lookup."""
    arch = _arch()
    g = make_workload("tf-quick")
    ce = CachedEvaluator(arch, g)
    reqs = _requests(g, arch, seed=2, n=2)
    fused = ce.eval_groups_batched(reqs, 8, backend="jax")
    assert len(ce._fused_cache) > 0
    # second fused call is served from the fused cache, same objects
    fused2 = ce.eval_groups_batched(reqs, 8, backend="jax")
    assert [ge for ge, _ in fused2] == [ge for ge, _ in fused]
    # the exact path must recompute from scratch and agree bit-for-bit
    # with a fresh uncached evaluator
    exact = ce.eval_groups_batched(reqs, 8)
    ref = Evaluator(arch, g).eval_requests_batch(reqs, 8)
    for (ge, _), (gr, _) in zip(exact, ref):
        assert (ge.delay_s, ge.energy_j) == (gr.delay_s, gr.energy_j)


def test_sa_fused_backend_rescores_winners_exact():
    """SAConfig(backend="jax"): proposals scored fused, best re-scored
    exactly at finalize — the reported cost must equal an independent
    exact evaluation of the returned mapping."""
    arch = _arch()
    g = make_workload("tf-quick")
    groups = partition_graph(g, arch, 8)
    cfg = SAConfig(iters=40, seed=3, n_chains=2, backend="jax")
    res = replica_exchange_sa(g, arch, groups, 8, cfg,
                              evaluator=CachedEvaluator(arch, g))
    final = Evaluator(arch, g).evaluate(res.mapping, 8)
    assert res.cost == final.cost(cfg.beta, cfg.gamma)
    assert res.energy_j == final.energy_j
    assert res.delay_s == final.delay_s


# ---------------------------------------------------------------------------
# jax REPLAY backend (analyze_requests(backend="jax"))
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ZOO)
def test_jax_replay_zoo_parity(spec):
    """The replay backend across the zoo — MoE carries non-1.0
    traffic_scale (top_k routed experts), MLA has the low-rank cubes and
    ragged CG rows; both must replay within float32 parity of the exact
    bincount."""
    arch = _arch()
    g = make_workload(spec)
    if spec == "moe-quick":
        scales = {l.traffic_scale for l in g.layers.values()}
        assert any(s != 1.0 for s in scales)     # routed experts present
    reqs = _requests(g, arch, seed=4, n=2)
    an = Evaluator(arch, g).analyzer
    ab_np = an.analyze_requests(reqs, 8)
    ab_jx = an.analyze_requests(reqs, 8, backend="jax")
    np.testing.assert_allclose(ab_jx.buf, ab_np.buf, rtol=2e-4, atol=1e-2)
    np.testing.assert_array_equal(ab_jx.weight_totals, ab_np.weight_totals)


def test_jax_replay_refuses_bad_dtypes():
    with pytest.raises(TypeError, match="int64 index stream"):
        _jax_replay(np.array([0, 1], np.int32),
                    np.array([1.0, 2.0]), 4)
    with pytest.raises(TypeError, match="float64 value stream"):
        _jax_replay(np.array([0, 1], np.int64),
                    np.array([1.0, 2.0], np.float32), 4)


def test_jax_replay_matches_bincount_exactly_shaped():
    """Direct replay check: same cells, float32-grade agreement."""
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 64, size=500)
    vals = rng.normal(size=500)
    out = _jax_replay(idx.astype(np.int64), vals.astype(np.float64), 64)
    ref = np.bincount(idx, weights=vals, minlength=64)
    assert out.shape == ref.shape and out.dtype == np.float64
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
