"""Sharded-compile tests on a small virtual-device mesh.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
(never set globally — smoke tests must see 1 device).  They exercise the same
bundle builders the 512-device dry-run uses, at miniature scale, plus the
roofline extraction and multi-device train-step numerics.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_sub(code: str, timeout: int = 900) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.splitlines()[-1])


def test_small_mesh_train_compile_and_roofline():
    code = textwrap.dedent("""
        import json
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import make_cell
        from repro.launch.roofline import analyze_compiled, model_flops_for
        cfg = get_config("qwen3-0.6b").reduced()
        shape = ShapeConfig("t", 64, 8, "train")
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        with mesh:
            b = make_cell(cfg, shape, mesh)
            compiled = b.fn.lower(*b.args).compile()
        rl = analyze_compiled("t", compiled, None,
                              model_flops_for(cfg, shape), 8)
        rec = rl.to_dict()
        print(json.dumps({"flops": rec["flops_per_device"],
                          "coll": rec["coll_bytes_per_device"],
                          "bneck": rec["bottleneck"]}))
    """)
    rec = _run_sub(code)
    assert rec["flops"] > 0
    assert rec["coll"] > 0           # FSDP/TP collectives must exist
    assert rec["bneck"] in ("compute", "memory", "collective")


def test_small_mesh_decode_and_prefill_compile():
    code = textwrap.dedent("""
        import json
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import make_cell
        out = {}
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        for arch in ("smollm-135m", "mamba2-370m"):
            cfg = get_config(arch).reduced()
            for kind, name in (("prefill", "p"), ("decode", "d")):
                shape = ShapeConfig(name, 128, 4, kind)
                with mesh:
                    b = make_cell(cfg, shape, mesh)
                    b.fn.lower(*b.args).compile()
                out[f"{arch}/{kind}"] = True
        print(json.dumps(out))
    """)
    rec = _run_sub(code)
    assert len(rec) == 4 and all(rec.values())


def test_multidevice_train_numerics_match_single():
    """A sharded train step must produce the same loss as single-device."""
    code = textwrap.dedent("""
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.data.pipeline import DataConfig, make_batch
        from repro.models import model_api
        from repro.nn.params import default_rules, tree_sharding
        from repro.launch.steps import get_param_axes, fit_batch_rules

        cfg = get_config("smollm-135m").reduced().replace(
            compute_dtype="float32")
        api = model_api(cfg)
        params, _ = api.init_params(jax.random.PRNGKey(0))
        batch_np = make_batch(DataConfig(vocab=cfg.vocab, seq_len=32,
                                         global_batch=8), 0)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()
                 if k != "mask"}
        loss_single = float(api.loss_fn(params, batch)[0])

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        rules = fit_batch_rules(default_rules(), 8, mesh)
        p_axes = get_param_axes(cfg)
        with mesh:
            shardings = tree_sharding(p_axes, rules, mesh)
            params_s = jax.tree.map(lambda x, s: jax.device_put(x, s),
                                    params, shardings)
            loss_sharded = float(jax.jit(
                lambda p, b: api.loss_fn(p, b, rules)[0])(params_s, batch))
        print(json.dumps({"single": loss_single, "sharded": loss_sharded}))
    """)
    rec = _run_sub(code)
    assert rec["single"] == pytest.approx(rec["sharded"], rel=2e-4)


def test_production_mesh_requires_devices():
    """make_production_mesh must refuse to build without enough devices."""
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(RuntimeError):
        make_production_mesh()           # this process has 1 CPU device
