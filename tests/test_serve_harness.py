"""Serving harness: trace generators, replay/SLO reports, the ``slo``
DSE objective, and the redesigned serve_loop timing contract."""

import jax
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.serve import (AnalyticalWaveExecutor, ServiceModel, Trace,
                         TrafficModel, WaveExecutor, make_trace,
                         poisson_trace, predict_slo, replay, resolve_traffic,
                         respec, saturation_sweep, service_model_from_delay)
from repro.serve.slo import SLO_SCALAR_KEY

SET = settings(max_examples=20, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])

SPEC = "poisson:rate=8,n=32,seed=0,plen=4..32,new=8..32"
MODEL = ServiceModel(prefill_s_per_token=1e-4, decode_s_per_token=1e-4)


# ---------------------------------------------------------------------------
# trace generators
# ---------------------------------------------------------------------------

def test_trace_determinism_and_fingerprint():
    a, b = make_trace(SPEC, seed=3), make_trace(SPEC, seed=3)
    assert a.to_jsonl() == b.to_jsonl()            # byte-identical
    assert a.fingerprint() == b.fingerprint()
    other = make_trace(SPEC, seed=4)
    assert other.to_jsonl() != a.to_jsonl()
    assert other.fingerprint() != a.fingerprint()


def test_trace_jsonl_roundtrip(tmp_path):
    t = make_trace(SPEC, seed=1)
    p = t.save(tmp_path / "t.jsonl")
    back = Trace.load(p)
    assert back.requests == t.requests
    assert (back.name, back.spec, back.seed) == (t.name, t.spec, t.seed)


def test_respec_overrides_rate():
    spec2 = respec(SPEC, rate=16)
    assert "rate=16" in spec2
    t = make_trace(spec2, seed=0)
    # roughly double the base spec's empirical rate (same seed, same n)
    assert t.arrival_rate() > make_trace(SPEC, seed=0).arrival_rate() * 1.5


def test_diurnal_trace_builds():
    t = make_trace("diurnal:rate=8,n=32,seed=0,plen=4..8,new=4..8,"
                   "period=30,peak=3", seed=0)
    arr = [r.arrival_s for r in t.requests]
    assert len(t) == 32 and arr == sorted(arr)
    assert all(r.prompt_len >= 1 and r.max_new >= 1 for r in t.requests)


@SET
@given(rate=st.floats(1.0, 32.0), seed=st.integers(0, 10_000))
def test_poisson_interarrival_mean(rate, seed):
    """Mean inter-arrival of n exponential draws ~ 1/rate (5 sigma)."""
    n = 256
    t = poisson_trace(rate, n, seed=seed)
    gaps = np.diff([0.0] + [r.arrival_s for r in t.requests])
    assert (gaps >= 0).all()
    tol = 5.0 / (rate * np.sqrt(n))                # 5 x the SE of the mean
    assert abs(gaps.mean() - 1.0 / rate) < tol


# ---------------------------------------------------------------------------
# replay harness
# ---------------------------------------------------------------------------

def test_replay_smoke_both_modes():
    trace = make_trace(SPEC, seed=0)
    for mode in ("wave", "continuous"):
        rep = replay(trace, MODEL, mode=mode, max_batch=4)
        s = rep.summary()
        assert s["mode"] == mode and s["timing"] == "virtual"
        assert len(rep.requests) == len(trace)
        assert 0.0 < s["mean_occupancy"] <= 1.0
        for k in ("p50", "p95", "p99"):
            assert s["ttft_s"][k] <= s["e2e_s"][k]


def test_latency_monotonicity_invariant():
    rep = replay(make_trace(SPEC, seed=2), MODEL, mode="continuous",
                 max_batch=4)
    for tl in rep.requests:
        assert tl.enqueue_t <= tl.start_t <= tl.first_token_t <= tl.finish_t
        assert tl.ttft_s <= tl.latency_s
        assert tl.n_tokens >= 1


def test_mixed_wave_latencies_differ():
    """Slots stopping at different decode steps must finish at different
    times — the pre-redesign API reported one shared wave duration."""
    from repro.serve import TraceRequest
    reqs = Trace(name="one-wave", spec="manual", seed=0, requests=[
        TraceRequest(rid=i, arrival_s=0.0, prompt_len=8, max_new=new)
        for i, new in enumerate((2, 9, 17, 30))])
    rep = replay(reqs, AnalyticalWaveExecutor(MODEL, max_batch=4),
                 mode="wave")
    assert rep.n_waves == 1
    lat = {tl.rid: tl.latency_s for tl in rep.requests}
    assert len(set(lat.values())) > 1
    by_new = {r.rid: r.max_new for r in reqs.requests}
    fins = {tl.rid: tl.finish_t for tl in rep.requests}
    # within the single wave, more decode steps -> later finish
    order = sorted(by_new, key=by_new.get)
    assert [fins[r] for r in order] == sorted(fins.values())


def test_replay_deterministic():
    trace = make_trace(SPEC, seed=0)
    a = replay(trace, MODEL, mode="continuous", max_batch=4).to_json()
    b = replay(trace, MODEL, mode="continuous", max_batch=4).to_json()
    assert a == b


def test_continuous_mode_rejects_opaque_executor():
    class Opaque:
        max_batch = 4

        def execute(self, wave):
            raise AssertionError("never called")
    with pytest.raises(ValueError, match="continuous"):
        replay(make_trace(SPEC, seed=0), Opaque(), mode="continuous")


def test_saturation_sweep_finds_knee():
    model = ServiceModel(prefill_s_per_token=1e-3, decode_s_per_token=1e-3)
    sat = saturation_sweep(
        lambda r: make_trace(respec(SPEC, rate=r), seed=0),
        lambda: model, rates=[1, 4, 16, 64, 256, 1024],
        mode="continuous", max_batch=4)
    assert sat["saturated"]
    assert sat["sat_rate_rps"] < 1024
    rows = sat["sweep"]
    assert rows[-1]["p99_e2e_s"] > sat["slo_mult"] * sat["ref_p99_e2e_s"]


# ---------------------------------------------------------------------------
# shared launcher CLI grammar
# ---------------------------------------------------------------------------

def test_workload_bindings_grammar():
    from repro.launch.cli import workload_bindings
    assert workload_bindings(["TF=tf-quick"]) == {"TF": "tf-quick"}
    assert workload_bindings(["tf-quick"], names=["TF"]) \
        == {"TF": "tf-quick"}
    # a parameterized bare spec's first '=' is part of the spec
    spec = "transformer:n_layers=1,d_model=64"
    assert workload_bindings([spec], names=["TF"]) == {"TF": spec}
    with pytest.raises(SystemExit):                # ambiguous bare spec
        workload_bindings(["tf-quick"], names=["A", "B"])
    with pytest.raises(SystemExit):                # unbound name
        workload_bindings(["A=tf-quick"], names=["A", "B"])


# ---------------------------------------------------------------------------
# slo: traffic models + analytical predictor
# ---------------------------------------------------------------------------

def test_resolve_traffic_forms():
    tm = resolve_traffic("chat-quick")
    assert isinstance(tm, TrafficModel) and tm.name == "chat-quick"
    adhoc = resolve_traffic(SPEC)
    assert adhoc.name == "adhoc" and adhoc.trace_spec == SPEC
    assert resolve_traffic(tm) is tm
    with pytest.raises(KeyError, match="chat-quick"):
        resolve_traffic("no-such-model")
    with pytest.raises(ValueError):
        resolve_traffic("bogus:rate=nope")


def test_traffic_fingerprint_stable():
    a = resolve_traffic("chat-quick").fingerprint()
    assert a == resolve_traffic("chat-quick").fingerprint()
    assert a.startswith("chat-quick.")
    assert a != resolve_traffic(SPEC).fingerprint()


def test_predict_slo_keys_and_cache():
    out = predict_slo(2e-4, "chat-quick", batch=8)
    for k in ("p50_e2e_s", "p95_e2e_s", SLO_SCALAR_KEY, "p99_ttft_s",
              "throughput_rps", "mean_occupancy"):
        assert k in out
    assert out == predict_slo(2e-4, "chat-quick", batch=8)   # lru hit
    # heavier per-token cost under identical traffic -> worse tail
    assert predict_slo(8e-4, "chat-quick", batch=8)[SLO_SCALAR_KEY] \
        > out[SLO_SCALAR_KEY]


def test_service_model_from_delay():
    m = service_model_from_delay(0.512, batch=8, seq_ref=64)
    assert m.decode_s_per_token == pytest.approx(0.512 / (8 * 64))
    assert m.prefill_s_per_token == pytest.approx(m.decode_s_per_token)
    m2 = service_model_from_delay(0.512, batch=8, seq_ref=64,
                                  decode_mult=2.0)
    assert m2.decode_s_per_token == pytest.approx(2 * m.decode_s_per_token)


# ---------------------------------------------------------------------------
# slo as a DSE objective
# ---------------------------------------------------------------------------

def _quick_dse():
    from repro.core.dse import DSEConfig, grid_candidates
    from repro.core.sa import SAConfig
    from repro.core.workloads import transformer
    grid = grid_candidates(
        72.0, mac_options=(512, 1024), cut_options=(1, 2),
        dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
        glb_options=(1024, 2048))
    wl = {"TF": transformer(n_layers=2, d_model=128, d_ff=256, seq=64,
                            name="tf-s")}
    return grid, wl, DSEConfig(batch=8, sa=SAConfig(iters=150, seed=0))


def test_slo_objective_off_is_bit_identical():
    """objective='geomean' (and the default) must not perturb the sweep."""
    from repro.core.dse import run_dse
    grid, wl, cfg = _quick_dse()
    base = run_dse(grid, wl, cfg, use_sa=False)
    explicit = run_dse(grid, wl, cfg, use_sa=False, objective="geomean")
    assert [(p.arch.label(), p.objective, p.energy_j, p.delay_s)
            for p in base] \
        == [(p.arch.label(), p.objective, p.energy_j, p.delay_s)
            for p in explicit]
    assert all(p.slo is None for p in base)


def test_fingerprint_obj_segment():
    """Default fingerprint has no obj= segment (PR-7 checkpoints replay);
    the slo objective stamps one BEFORE :wl= (realize header contract)."""
    import dataclasses

    from repro.core.explore import ExplorationEngine
    grid, wl, cfg = _quick_dse()
    with ExplorationEngine(wl, cfg) as eng:
        fp = eng._fingerprint(True)
    assert ":obj=" not in fp and ":wl=" in fp
    slo_cfg = dataclasses.replace(cfg, objective="slo", traffic=SPEC)
    with ExplorationEngine(wl, slo_cfg) as eng:
        fp_slo = eng._fingerprint(True)
    assert ":obj=slo(adhoc." in fp_slo
    assert fp_slo.index(":obj=") < fp_slo.index(":wl=")
    assert fp_slo.split(":wl=")[1] == fp.split(":wl=")[1]


def test_slo_objective_requires_traffic():
    from repro.core.dse import run_dse
    grid, wl, cfg = _quick_dse()
    with pytest.raises(ValueError, match="traffic"):
        run_dse(grid[:2], wl, cfg, use_sa=False, objective="slo")


def test_slo_objective_reranks_sa_grid():
    """The acceptance recipe: with SA mappings the quick grid's (E, D)
    ordering is not monotone in D, so the convex queueing tail re-ranks
    candidates the geomean objective ordered the other way."""
    from repro.core.dse import run_dse
    grid, wl, cfg = _quick_dse()
    traffic = "poisson:rate=71267.4,n=48,seed=0,plen=4..32,new=8..32"
    base = run_dse(grid, wl, cfg, use_sa=True)
    slo = run_dse(grid, wl, cfg, use_sa=True, objective="slo",
                  traffic=traffic)
    # same mappings, same physics: per-candidate (E, D) identical
    assert sorted((p.arch.label(), p.energy_j, p.delay_s) for p in base) \
        == sorted((p.arch.label(), p.energy_j, p.delay_s) for p in slo)
    assert [p.arch.label() for p in base] != [p.arch.label() for p in slo]
    for p in slo:
        assert p.slo is not None
        assert p.objective == pytest.approx(
            p.mc * p.energy_j * p.slo[SLO_SCALAR_KEY])


# ---------------------------------------------------------------------------
# serve_loop redesign: queue/executor split + per-request timing
# ---------------------------------------------------------------------------

def _tiny_model():
    from repro.configs import get_config
    from repro.models import model_api
    cfg = get_config("smollm-135m").reduced().replace(
        n_layers=2, d_model=64, vocab=256, d_ff=128)
    params, _ = model_api(cfg).init_params(jax.random.PRNGKey(0))
    return cfg, params


def test_request_queue_fifo_and_stamping():
    from repro.runtime.serve_loop import Request, RequestQueue
    q = RequestQueue()
    for i in range(5):
        q.submit(Request(rid=i, prompt=np.array([1], np.int32),
                         enqueue_t=float(i + 1)))
    q.submit(Request(rid=5, prompt=np.array([1], np.int32)))
    assert q.pending[-1].enqueue_t > 0.0            # wall-clock stamped
    assert [r.rid for r in q.next_wave(4)] == [0, 1, 2, 3]
    assert len(q) == 2


def test_model_executor_satisfies_protocol():
    from repro.runtime.serve_loop import ModelWaveExecutor
    cfg, params = _tiny_model()
    ex = ModelWaveExecutor(cfg, params, max_batch=2, max_seq=64,
                           cache_len=32)
    assert isinstance(ex, WaveExecutor)
    assert ex.cache_len == 32
    trace = make_trace("poisson:rate=50,n=3,seed=0,plen=2..6,new=2..4",
                       seed=0)
    rep = replay(trace, ex, mode="wave")
    assert len(rep.requests) == 3
    for tl in rep.requests:
        assert tl.finish_t >= tl.first_token_t >= tl.start_t


def test_per_request_latency_differs_in_mixed_wave():
    """Regression pin: the old API's shared wave-level latency is wrong."""
    from repro.runtime.serve_loop import Request, Server
    cfg, params = _tiny_model()
    srv = Server(cfg, params, max_batch=4, max_seq=64, eos_id=-1)
    rng = np.random.default_rng(0)
    for i, budget in enumerate((2, 9, 16)):        # one mixed-length wave
        srv.submit(Request(rid=i, prompt=rng.integers(
            1, cfg.vocab, size=4).astype(np.int32), max_new=budget,
            enqueue_t=1.0))
    results = {r.rid: r for r in srv.run_until_empty()}
    lats = [results[i].latency_s for i in range(3)]
    assert len(set(lats)) == 3                     # not one shared number
    assert lats == sorted(lats)                    # longer budget -> later
    for r in results.values():
        assert r.finish_t > r.start_t >= r.enqueue_t
        assert r.latency_s == pytest.approx(r.finish_t - r.enqueue_t)


def test_max_new_one_runs_zero_decode_steps():
    """Done-mask fix: a max_new=1 wave never launches a decode step (the
    old loop burned one and leaked a token past the budget)."""
    from repro.runtime.serve_loop import ModelWaveExecutor, Request
    cfg, params = _tiny_model()
    ex = ModelWaveExecutor(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    out, ntok, cost = ex.run_wave([Request(
        rid=0, prompt=np.array([3, 4, 5], np.int32), max_new=1)])
    assert cost.step_s == []                       # zero decode launches
    assert ntok.tolist() == [1] and out.shape == (1, 1)
