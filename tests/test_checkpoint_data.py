"""Checkpointing (atomicity, retention, elastic restore) + data pipeline."""

import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager, load_step, restore, save
from repro.data.pipeline import DataConfig, Prefetcher, make_batch


def _tree():
    return {"a": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "b": jnp.ones((2,), jnp.int32),
            "step": jnp.zeros((), jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    p = save(tmp_path / "ck.npz", t, step=7)
    out = restore(p, jax.tree.map(np.asarray, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert load_step(p) == 7


def test_save_atomic_no_tmp_left(tmp_path):
    save(tmp_path / "ck.npz", _tree(), 1)
    leftovers = list(tmp_path.glob("*.tmp*"))
    assert not leftovers


def test_restore_shape_mismatch_raises(tmp_path):
    p = save(tmp_path / "ck.npz", _tree(), 1)
    bad = {"a": {"w": np.zeros((5, 5), np.float32)},
           "b": np.ones((2,), np.int32), "step": np.zeros((), np.int32)}
    with pytest.raises(ValueError):
        restore(p, bad)


def test_manager_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(_tree(), s)
    assert mgr.latest_step() == 40
    assert mgr.steps() == [30, 40]


def test_manager_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    mgr.save(_tree(), 5)
    mgr.wait()
    assert mgr.latest_step() == 5
    got, step = mgr.restore_latest(jax.tree.map(np.asarray, _tree()))
    assert step == 5 and got is not None


def test_elastic_restore_resharded(tmp_path):
    """Save under one sharding, restore under a different one (host round
    trip re-shards) — the elastic-rescale path."""
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    p = save(tmp_path / "ck.npz", t, 1)
    dev = jax.devices()[0]
    shardings = {"w": jax.sharding.SingleDeviceSharding(dev)}
    out = restore(p, jax.tree.map(np.asarray, t), shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=300, seq_len=16, global_batch=2)
    b = make_batch(cfg, 3)
    b2 = make_batch(DataConfig(vocab=300, seq_len=17, global_batch=2), 3)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_steps_differ():
    cfg = DataConfig(vocab=300, seq_len=16, global_batch=2)
    assert not (make_batch(cfg, 0)["tokens"]
                == make_batch(cfg, 1)["tokens"]).all()


def test_prefetcher_in_order_and_resumable():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(lambda s: make_batch(cfg, s), start_step=5, depth=2)
    try:
        for expect in (5, 6, 7):
            step, batch = pf.next()
            assert step == expect
            np.testing.assert_array_equal(batch["tokens"],
                                          make_batch(cfg, expect)["tokens"])
    finally:
        pf.close()


def test_embeds_batch_deterministic():
    from repro.data.pipeline import make_embeds_batch
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    a = make_embeds_batch(cfg, 2, d_model=16)
    b = make_embeds_batch(cfg, 2, d_model=16)
    np.testing.assert_array_equal(a["embeds"], b["embeds"])
    assert a["embeds"].shape == (2, 8, 16)
