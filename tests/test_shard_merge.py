"""Sharded sweeps + checkpoint merging: (candidate x workload) task model,
shard/worker bit-identity, merge_checkpoints properties (last-wins,
corrupt-shard set-aside, fingerprint refusal), LMS mapping serialization,
schema-v1 -> v2 migration, and the n_chains=2 degeneracy fix."""

import json
import tempfile
from pathlib import Path

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import dse as dse_mod
from repro.core.dse import (DSEConfig, evaluate_candidate, grid_candidates,
                            run_dse)
from repro.core.encoding import random_lms
from repro.core.explore import (ExplorationEngine, ResumableSweep,
                                arch_to_dict, candidate_key, derive_seed,
                                derive_task_seed, mapping_from_jsonable,
                                mapping_to_jsonable, merge_checkpoints,
                                migrate_v1_record, pareto_frontier,
                                parse_shard_spec)
from repro.core.graph_partition import partition_graph
from repro.core.hw import simba_arch
from repro.core.sa import SAConfig, sa_optimize
from repro.core.workloads import transformer

SET = settings(max_examples=25, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def _tf_small(name="tf-s", seq=64):
    return transformer(n_layers=2, d_model=128, d_ff=256, seq=seq, name=name)


def _grid(n=6):
    cands = grid_candidates(
        72.0, mac_options=(512, 1024), cut_options=(1, 2),
        dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
        glb_options=(1024, 2048))
    assert len(cands) >= n
    return cands[:n]


def _cfg(iters=50, seed=3, **kw):
    return DSEConfig(batch=8, sa=SAConfig(iters=iters, seed=seed), **kw)


def _sig(points):
    return [(p.arch, p.objective, p.energy_j, p.delay_s) for p in points]


# ---------------------------------------------------------------------------
# Task seeds
# ---------------------------------------------------------------------------

def test_task_seed_workload_zero_matches_candidate_seed():
    """wl_idx=0 reduces to the v1 per-candidate seed — what makes migrated
    single-workload checkpoints fully reusable."""
    for base, ci in ((0, 0), (3, 7), (123, 41)):
        assert derive_task_seed(base, ci, 0) == derive_seed(base, ci)


def test_task_seeds_distinct_across_grid():
    seeds = {derive_task_seed(0, ci, wi)
             for ci in range(40) for wi in range(5)}
    assert len(seeds) == 200
    assert derive_task_seed(0, 1, 2) != derive_task_seed(0, 2, 1)


def test_parse_shard_spec():
    assert parse_shard_spec("0/1") == (0, 1)
    assert parse_shard_spec("2/3") == (2, 3)
    for bad in ("3/3", "-1/2", "1", "a/b", "1/0"):
        with pytest.raises(ValueError):
            parse_shard_spec(bad)


# ---------------------------------------------------------------------------
# Shard x worker bit-identity (the acceptance matrix)
# ---------------------------------------------------------------------------

def test_sharded_merged_sweep_bit_identical_across_workers(tmp_path):
    """n_workers in {1,4} x shards in {1,3}: the merged+resumed sweep's
    best-candidate metrics and Pareto frontier are bit-identical to the
    serial unsharded run."""
    g = _tf_small()
    cands = _grid(6)
    full = run_dse(cands, {"TF": g}, _cfg())            # serial, unsharded
    for n_workers in (1, 4):
        shard_paths = []
        for i in range(3):
            ck = tmp_path / f"w{n_workers}.shard{i}of3.jsonl"
            part = run_dse(cands, {"TF": g}, _cfg(), n_workers=n_workers,
                           shard=(i, 3), checkpoint=ck)
            assert len(part) == 2               # 6 candidates, stride 3
            shard_paths.append(ck)
        merged = tmp_path / f"w{n_workers}.merged.jsonl"
        report = merge_checkpoints(shard_paths, merged)
        assert report.n_records == 6 and not report.skipped
        pts = run_dse(cands, {"TF": g}, _cfg(), checkpoint=merged)
        assert _sig(pts) == _sig(full)
        assert _sig(pareto_frontier(pts)) == _sig(pareto_frontier(full))


def test_multi_workload_task_fanout_and_sharding(tmp_path):
    """Two workloads -> 2 tasks per candidate; parallel and sharded-merged
    runs match serial, and the reduction matches evaluate_candidate."""
    workloads = {"A": _tf_small("tf-a"), "B": _tf_small("tf-b", seq=96)}
    cands = _grid(4)
    cfg = _cfg()
    serial = run_dse(cands, workloads, cfg)
    assert all(set(p.per_workload) == {"A", "B"} for p in serial)
    par = run_dse(cands, workloads, cfg, n_workers=2)
    assert _sig(serial) == _sig(par)
    # the standalone per-candidate API agrees with the engine's fan-out
    by_arch = {p.arch: p for p in serial}
    for ci, arch in enumerate(cands):
        pt = evaluate_candidate(arch, workloads, cfg, cand_idx=ci)
        assert (pt.objective, pt.energy_j, pt.delay_s) == \
            (by_arch[arch].objective, by_arch[arch].energy_j,
             by_arch[arch].delay_s)
    # sharded across 2 shards, merged, resumed: bit-identical
    shard_paths = []
    for i in range(2):
        ck = tmp_path / f"mw.shard{i}of2.jsonl"
        run_dse(cands, workloads, cfg, shard=(i, 2), checkpoint=ck)
        shard_paths.append(ck)
    merged = tmp_path / "mw.merged.jsonl"
    assert merge_checkpoints(shard_paths, merged).n_records == 8
    pts = run_dse(cands, workloads, cfg, checkpoint=merged)
    assert _sig(pts) == _sig(serial)


def test_sharding_composes_with_screening(tmp_path):
    """Screening is replicated per shard (deterministic), so the union of
    shard results equals the screened unsharded run."""
    g = _tf_small()
    cands = _grid(6)
    full = run_dse(cands, {"TF": g}, _cfg(), screen_keep=0.5)
    parts = []
    for i in range(3):
        ck = tmp_path / f"scr.shard{i}of3.jsonl"
        parts += run_dse(cands, {"TF": g}, _cfg(), screen_keep=0.5,
                         shard=(i, 3), checkpoint=ck)
    assert sorted(_sig(parts), key=repr) == sorted(_sig(full), key=repr)


def test_bad_shard_spec_rejected():
    g = _tf_small()
    with pytest.raises(ValueError, match="bad shard"):
        run_dse(_grid(2), {"TF": g}, _cfg(iters=10), shard=(2, 2))


# ---------------------------------------------------------------------------
# merge_checkpoints properties
# ---------------------------------------------------------------------------

def _write_shard(path: Path, fingerprint, records):
    """records: iterable of (key, value) pairs, written in order."""
    lines = []
    if fingerprint is not None:
        lines.append(json.dumps({"_config": fingerprint}))
    for k, v in records:
        lines.append(json.dumps({"_key": str(k), "x": v}))
    path.write_text("".join(l + "\n" for l in lines))


@SET
@given(shards=st.lists(
    st.lists(st.tuples(st.integers(0, 9), st.integers(0, 10_000)),
             min_size=0, max_size=8),
    min_size=1, max_size=4))
def test_merge_last_wins_matches_sequential_update(shards):
    """Disjoint or overlapping shards: merged records == a dict built by
    updating in shard order (last-wins), regardless of overlap pattern."""
    with tempfile.TemporaryDirectory() as td:
        paths = []
        expect = {}
        for i, recs in enumerate(shards):
            p = Path(td) / f"s{i}.jsonl"
            _write_shard(p, "fp", recs)
            paths.append(p)
            for k, v in recs:
                expect[str(k)] = {"x": v}
        out = Path(td) / "merged.jsonl"
        report = merge_checkpoints(paths, out)
        assert report.records == expect
        assert report.fingerprint == "fp" and not report.skipped
        # the written file parses back to the same records
        reread = ResumableSweep(out, config_fingerprint="fp")
        assert reread.as_dict() == expect


def test_merge_disjoint_and_overlapping_shards(tmp_path):
    a = tmp_path / "a.jsonl"
    b = tmp_path / "b.jsonl"
    _write_shard(a, "fp", [("k1", 1), ("k2", 2)])
    _write_shard(b, "fp", [("k2", 99), ("k3", 3)])   # overlaps a on k2
    report = merge_checkpoints([a, b], tmp_path / "m.jsonl")
    assert report.records == {"k1": {"x": 1}, "k2": {"x": 99},
                              "k3": {"x": 3}}          # b wins k2


def test_merge_corrupt_shard_set_aside(tmp_path):
    """A mid-file corrupt shard is excluded; the others still merge.  A
    truncated *trailing* line is tolerated within a shard."""
    ok = tmp_path / "ok.jsonl"
    bad = tmp_path / "bad.jsonl"
    trunc = tmp_path / "trunc.jsonl"
    missing = tmp_path / "missing.jsonl"
    _write_shard(ok, "fp", [("a", 1)])
    bad.write_text(json.dumps({"_config": "fp"}) + "\n{broken\n"
                   + json.dumps({"_key": "b", "x": 2}) + "\n")
    _write_shard(trunc, "fp", [("c", 3)])
    with trunc.open("a") as f:
        f.write('{"_key": "d", "x":')         # killed mid-write
    report = merge_checkpoints([ok, bad, trunc, missing],
                               tmp_path / "m.jsonl")
    assert report.records == {"a": {"x": 1}, "c": {"x": 3}}
    assert {p.name for p, _ in report.skipped} == {"bad.jsonl",
                                                   "missing.jsonl"}
    # source shards are never modified by a merge
    assert "{broken" in bad.read_text()


def test_merge_mismatched_fingerprints_refused(tmp_path):
    a = tmp_path / "a.jsonl"
    b = tmp_path / "b.jsonl"
    _write_shard(a, "fp1", [("a", 1)])
    _write_shard(b, "fp2", [("b", 2)])
    with pytest.raises(ValueError, match="mismatched"):
        merge_checkpoints([a, b], tmp_path / "m.jsonl")
    assert not (tmp_path / "m.jsonl").exists()
    with pytest.raises(ValueError, match="expected"):
        merge_checkpoints([a], tmp_path / "m.jsonl",
                          expect_fingerprint="fp2")


def test_merge_all_shards_unusable_raises(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{broken\n{"_key": "b", "x": 2}\n')
    with pytest.raises(ValueError, match="no usable shards"):
        merge_checkpoints([bad, tmp_path / "gone.jsonl"])


# ---------------------------------------------------------------------------
# LMS mapping (de)serialization
# ---------------------------------------------------------------------------

@SET
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_mapping_roundtrip_through_json(seed):
    """random mappings survive serialize -> json -> deserialize exactly."""
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    rng = np.random.default_rng(seed)
    mapping = [(grp, random_lms(grp, g, arch.n_cores, arch.n_dram, rng))
               for grp in groups]
    wire = json.loads(json.dumps(mapping_to_jsonable(mapping)))
    back = mapping_from_jsonable(wire)
    assert back == mapping
    for grp, lms in back:
        lms.validate(grp, g, arch.n_cores, arch.n_dram)


def test_mapping_from_jsonable_rejects_damaged_record():
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    rng = np.random.default_rng(0)
    mapping = [(groups[0], random_lms(groups[0], g, arch.n_cores,
                                      arch.n_dram, rng))]
    wire = mapping_to_jsonable(mapping)
    name = next(iter(wire[0]["lms"]))
    wire[0]["lms"][name]["cg"] = wire[0]["lms"][name]["cg"][:-1]  # break it
    with pytest.raises(ValueError):
        mapping_from_jsonable(wire)


def test_keep_mappings_survive_resume_and_merge(tmp_path, monkeypatch):
    g = _tf_small()
    cands = _grid(2)
    cfg = _cfg(iters=40, keep_mappings=True)
    ck = tmp_path / "maps.jsonl"
    first = run_dse(cands, {"TF": g}, cfg, checkpoint=ck)
    assert all(set(p.mappings) == {"TF"} for p in first)

    calls = []
    real = dse_mod.evaluate_task

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(dse_mod, "evaluate_task", counting)
    resumed = run_dse(cands, {"TF": g}, cfg, checkpoint=ck)
    assert not calls                      # everything came from the file
    assert _sig(resumed) == _sig(first)
    by_arch = {p.arch: p for p in first}
    for p in resumed:
        assert p.mappings == by_arch[p.arch].mappings    # not metrics-only
        for grp, lms in p.mappings["TF"]:
            lms.validate(grp, g, p.arch.n_cores, p.arch.n_dram)
    # a merged checkpoint carries the mappings too
    merged = tmp_path / "maps.merged.jsonl"
    merge_checkpoints([ck], merged)
    remerged = run_dse(cands, {"TF": g}, cfg, checkpoint=merged)
    assert not calls
    assert remerged[0].mappings == first[0].mappings


def test_metrics_only_checkpoint_upgrades_to_mappings(tmp_path, monkeypatch):
    """Resuming a metrics-only sweep with keep_mappings=True recomputes the
    tasks (same fingerprint) and upgrades their records in place."""
    g = _tf_small()
    cands = _grid(2)
    ck = tmp_path / "up.jsonl"
    run_dse(cands, {"TF": g}, _cfg(iters=40), checkpoint=ck)
    assert "mapping" not in ck.read_text()

    calls = []
    real = dse_mod.evaluate_task

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(dse_mod, "evaluate_task", counting)
    pts = run_dse(cands, {"TF": g}, _cfg(iters=40, keep_mappings=True),
                  checkpoint=ck)
    assert len(calls) == 2                # metrics-only records recomputed
    assert all(p.mappings for p in pts)
    calls.clear()
    run_dse(cands, {"TF": g}, _cfg(iters=40, keep_mappings=True),
            checkpoint=ck)
    assert not calls                      # records now carry mappings


# ---------------------------------------------------------------------------
# Schema v1 -> v2 migration
# ---------------------------------------------------------------------------

def _v1_fingerprint(workloads, cfg, use_sa=True):
    with ExplorationEngine(workloads, cfg) as eng:
        return eng._fingerprint(use_sa, schema=1)


def _write_v1_checkpoint(path, fingerprint, rows):
    """rows: (arch, seed, point-ish dict with per_workload)."""
    lines = [json.dumps({"_config": fingerprint})]
    for arch, seed, per_workload in rows:
        lines.append(json.dumps({
            "_key": candidate_key(arch), "seed": seed,
            "arch": arch_to_dict(arch), "mc": 1.0, "energy_j": 1.0,
            "delay_s": 1.0, "objective": 1.0,
            "per_workload": per_workload}))
    path.write_text("".join(l + "\n" for l in lines))


def test_v1_checkpoint_migrates_and_resumes_single_workload(tmp_path,
                                                            monkeypatch):
    """A PR-2 (schema v1) checkpoint of a single-workload sweep resumes in
    full: records are split into task records and the v1 candidate seed
    matches the v2 seed of workload 0."""
    g = _tf_small()
    cands = _grid(3)
    cfg = _cfg(iters=40)
    fresh = run_dse(cands, {"TF": g}, cfg)
    by_arch = {p.arch: p for p in fresh}
    ck = tmp_path / "v1.jsonl"
    _write_v1_checkpoint(
        ck, _v1_fingerprint({"TF": g}, cfg),
        [(arch, derive_seed(cfg.sa.seed, ci),
          {"TF": list(by_arch[arch].per_workload["TF"])})
         for ci, arch in enumerate(cands)])

    calls = []
    real = dse_mod.evaluate_task

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(dse_mod, "evaluate_task", counting)
    resumed = run_dse(cands, {"TF": g}, cfg, checkpoint=ck)
    assert not calls                     # fully reused after migration
    assert _sig(resumed) == _sig(fresh)
    text = ck.read_text()                # rewritten under the v2 schema
    assert '"dse:v2:' in text and "per_workload" not in text
    assert "|wl=TF" in text


def test_v1_checkpoint_multi_workload_recomputes_independent_seeds(
        tmp_path, monkeypatch):
    """v1 ran every workload under one candidate seed; v2 gives workload
    index >= 1 its own seed, so those migrated records must recompute
    (seed gate) while workload 0's records are reused."""
    workloads = {"A": _tf_small("tf-a"), "B": _tf_small("tf-b", seq=96)}
    cands = _grid(2)
    cfg = _cfg(iters=40)
    fresh = run_dse(cands, workloads, cfg)
    by_arch = {p.arch: p for p in fresh}
    ck = tmp_path / "v1mw.jsonl"
    # "A" carries the true v2 values (reused); "B" carries garbage that the
    # seed gate must refuse (v1 would have computed B under the shared seed)
    _write_v1_checkpoint(
        ck, _v1_fingerprint(workloads, cfg),
        [(arch, derive_seed(cfg.sa.seed, ci),
          {"A": list(by_arch[arch].per_workload["A"]), "B": [1e9, 1e9]})
         for ci, arch in enumerate(cands)])

    calls = []
    real = dse_mod.evaluate_task

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(dse_mod, "evaluate_task", counting)
    resumed = run_dse(cands, workloads, cfg, checkpoint=ck)
    assert len(calls) == 2               # one "B" task per candidate
    assert _sig(resumed) == _sig(fresh)  # garbage never surfaced


def test_migrate_v1_record_shape():
    out = migrate_v1_record("K", {"seed": 7, "arch": {"a": 1},
                                  "per_workload": {"B": [2.0, 3.0],
                                                   "A": [4.0, 5.0]}})
    assert [k for k, _ in out] == ["K|wl=A", "K|wl=B"]   # sorted names
    rec = dict(out)["K|wl=B"]
    assert rec["seed"] == 7 and rec["energy_j"] == 2.0 \
        and rec["delay_s"] == 3.0
    assert migrate_v1_record("K", {"seed": 1}) == []     # malformed: drop


# ---------------------------------------------------------------------------
# n_chains=2 degeneracy fix
# ---------------------------------------------------------------------------

def test_sa_optimize_two_chains_warns_and_runs_minimum_ladder():
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    with pytest.warns(RuntimeWarning, match="n_chains=2"):
        r2 = sa_optimize(g, arch, groups, 8,
                         SAConfig(iters=120, seed=0, n_chains=2))
    r3 = sa_optimize(g, arch, groups, 8,
                     SAConfig(iters=120, seed=0, n_chains=3))
    assert (r2.cost, r2.energy_j, r2.delay_s) == \
        (r3.cost, r3.energy_j, r3.delay_s)
    assert r2.proposed == r3.proposed
