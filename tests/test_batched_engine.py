"""Batched mapping-evaluation engine: SoA LMS batches, batch-axis
bit-identity vs the scalar engine, lockstep replica exchange, batched
screening, the sort-based Pareto sweep and the cached group-draw CDF."""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.dse import DSEConfig, grid_candidates
from repro.core.encoding import (LMS, MS, pack_lms_batch, random_lms,
                                 unpack_lms_batch)
from repro.core.evaluator import (CachedEvaluator, Evaluator,
                                  analysis_signature)
from repro.core.explore import (ExplorationEngine, _pareto_mask_quadratic,
                                _pareto_mask_sweep, replica_exchange_sa)
from repro.core.graph_partition import partition_graph
from repro.core.hw import ArchConfig
from repro.core.sa import SAConfig, _Op, group_draw_cdf, sa_optimize
from repro.core.tangram import tangram_map
from repro.core.workloads import transformer

SET = settings(max_examples=20, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def _arch():
    return ArchConfig(x_cores=4, y_cores=3, xcut=2, ycut=1,
                      noc_bw=16.0, d2d_bw=8.0, dram_bw=64.0,
                      glb_kb=512, macs_per_core=256)


def _graph():
    return transformer(n_layers=1, d_model=64, d_ff=128, seq=32,
                       name="tf-batched")


@pytest.fixture(scope="module")
def setup():
    arch, g = _arch(), _graph()
    groups = partition_graph(g, arch, 8)
    init = tangram_map(groups, g, arch)
    return arch, g, groups, init


def _random_batch(arch, g, grp, seed, n):
    """n random mappings of one group (ragged CG lengths included)."""
    rng = np.random.default_rng(seed)
    return [random_lms(grp, g, arch.n_cores, arch.n_dram, rng)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# SoA pack / unpack
# ---------------------------------------------------------------------------

@SET
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 6))
def test_pack_unpack_roundtrip(seed, n):
    arch, g = _arch(), _graph()
    grp = partition_graph(g, arch, 8)[0]
    batch = _random_batch(arch, g, grp, seed, n)
    packed = pack_lms_batch(batch, names=grp.names)
    assert packed.batch_size == n
    assert packed.names == grp.names
    assert packed.cg.shape[2] == max(m.nc for lms in batch
                                     for m in lms.ms.values())
    out = unpack_lms_batch(packed)
    assert [lms.cache_key() for lms in out] \
        == [lms.cache_key() for lms in batch]


def test_pack_rejects_bad_batches(setup):
    arch, g, groups, init = setup
    grp, lms = init[0]
    with pytest.raises(ValueError, match="empty"):
        pack_lms_batch([])
    other = {n: m for n, m in lms.ms.items()}
    name = next(iter(other))
    bad = dict(other)
    bad["not-a-layer"] = bad.pop(name)
    with pytest.raises(ValueError, match="layers"):
        pack_lms_batch([lms, LMS(ms=bad)], names=grp.names)


def test_unpack_revalidates_corrupt_rows(setup):
    arch, g, groups, init = setup
    grp, lms = init[0]
    packed = pack_lms_batch([lms], names=grp.names)
    packed.part[0, 0, 0] += 1          # Part product != |CG| now
    with pytest.raises(ValueError):
        unpack_lms_batch(packed)


# ---------------------------------------------------------------------------
# Batch-axis bit-identity vs the scalar engine
# ---------------------------------------------------------------------------

@SET
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 5))
def test_batched_eval_bit_identical_to_scalar(seed, n):
    """pack -> batched analyze/eval -> row b == scalar eval_group, exactly
    (the acceptance contract of the batched engine)."""
    arch, g = _arch(), _graph()
    groups = partition_graph(g, arch, 8)
    ev_scalar = Evaluator(arch, g)
    ev_batch = Evaluator(arch, g)
    for grp in groups:
        batch = _random_batch(arch, g, grp, seed, n)
        packed = pack_lms_batch(batch, names=grp.names)
        rows = ev_batch.eval_group_batch(grp, unpack_lms_batch(packed), 8)
        for lms, (geb, anb) in zip(batch, rows):
            ges, ans = ev_scalar.eval_group(grp, lms, 8)
            assert ges.delay_s == geb.delay_s
            assert ges.energy_j == geb.energy_j
            assert ges.stage_time_s == geb.stage_time_s
            assert ges.bottleneck == geb.bottleneck
            assert ges.glb_overflow_bytes == geb.glb_overflow_bytes
            assert ges.energy_breakdown == geb.energy_breakdown
            for f in ("core_macs", "edge_bytes", "edge_bytes_amortized",
                      "dram_bytes", "dram_bytes_amortized", "core_glb_need",
                      "core_in_bytes", "core_out_bytes", "core_time_s",
                      "glb_rw_bytes"):
                assert np.array_equal(getattr(ans, f), getattr(anb, f)), f
            assert ans.weight_dram_bytes_total == anb.weight_dram_bytes_total


def test_mixed_group_requests_bit_identical(setup):
    """eval_requests_batch may mix layer groups in one replay."""
    arch, g, groups, init = setup
    rng = np.random.default_rng(7)
    ops = _Op(g, arch, rng)
    reqs = []
    for grp, lms in init:
        cur = lms
        for _ in range(4):
            cand = (ops.op1(grp, cur) or ops.op2(grp, cur)
                    or ops.op5(grp, cur) or cur)
            reqs.append((grp, cand))
            cur = cand
    rows = Evaluator(arch, g).eval_requests_batch(reqs, 8)
    ev = Evaluator(arch, g)
    for (grp, lms), (geb, _) in zip(reqs, rows):
        ges, _ = ev.eval_group(grp, lms, 8)
        assert (ges.delay_s, ges.energy_j) == (geb.delay_s, geb.energy_j)


def test_cached_batched_path_matches_and_caches(setup):
    arch, g, groups, init = setup
    grp, lms = init[0]
    batch = _random_batch(arch, g, grp, 3, 4) + [lms, lms]   # duplicates
    reqs = [(grp, l) for l in batch]
    ev = CachedEvaluator(arch, g)
    first = ev.eval_groups_batched(reqs, 8)
    assert ev.cache_info()["misses"] == 5          # dedup within the batch
    again = ev.eval_groups_batched(reqs, 8)
    assert ev.cache_info()["misses"] == 5          # pure hits now
    for (ga, _), (gb, _) in zip(first, again):
        assert ga is gb                            # same cached tuples
    scalar = CachedEvaluator(arch, g)
    for (grp_, l), (ge, _) in zip(reqs, first):
        gs, _ = scalar.eval_group(grp_, l, 8)
        assert (gs.delay_s, gs.energy_j) == (ge.delay_s, ge.energy_j)


def test_jax_backend_parity(setup):
    """Opt-in jax segment-sum replay: parity-grade, never bit-identical."""
    arch, g, groups, init = setup
    grp, lms = init[0]
    batch = _random_batch(arch, g, grp, 5, 3)
    an = Evaluator(arch, g).analyzer
    ab_np = an.analyze_batch(grp, batch, 8, backend="numpy")
    ab_jx = an.analyze_batch(grp, batch, 8, backend="jax")
    np.testing.assert_allclose(ab_jx.buf, ab_np.buf, rtol=2e-4, atol=1e-2)
    with pytest.raises(ValueError, match="backend"):
        an.analyze_batch(grp, batch, 8, backend="torch")


# ---------------------------------------------------------------------------
# Lockstep replica exchange
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n_chains", [(0, 3), (11, 4)])
def test_lockstep_trajectory_equals_serial_loop(seed, n_chains):
    arch, g = _arch(), _graph()
    groups = partition_graph(g, arch, 8)
    cfg = SAConfig(iters=200, seed=seed, n_chains=n_chains, lockstep=True)
    from dataclasses import replace
    rl = replica_exchange_sa(g, arch, groups, 8, cfg)
    rs = replica_exchange_sa(g, arch, groups, 8,
                             replace(cfg, lockstep=False))
    assert rl.cost == rs.cost
    assert rl.energy_j == rs.energy_j and rl.delay_s == rs.delay_s
    assert rl.proposed == rs.proposed and rl.accepted == rs.accepted
    assert rl.swap_attempts == rs.swap_attempts
    assert rl.swap_accepts == rs.swap_accepts
    assert [(grp.names, lms.cache_key()) for grp, lms in rl.mapping] \
        == [(grp.names, lms.cache_key()) for grp, lms in rs.mapping]


def test_lockstep_reference_chain_keeps_single_chain_guarantee():
    """Chain 0 is unswapped, so lockstep n_chains>1 can never be worse than
    the (unchanged) serial single-chain result on the same seed."""
    arch, g = _arch(), _graph()
    groups = partition_graph(g, arch, 8)
    single = sa_optimize(g, arch, groups, 8, SAConfig(iters=250, seed=2))
    multi = sa_optimize(g, arch, groups, 8,
                        SAConfig(iters=250, seed=2, n_chains=4))
    assert multi.cost <= single.cost


# ---------------------------------------------------------------------------
# Batched screening
# ---------------------------------------------------------------------------

def _quick_cands(n=8):
    return grid_candidates(
        72.0, mac_options=(512, 1024), cut_options=(1, 2),
        dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
        glb_options=(1024, 2048))[:n]


def test_batched_screen_bit_identical_to_reference():
    g = transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")
    cfg = DSEConfig(batch=8, sa=SAConfig(iters=40, seed=0))
    cands = _quick_cands()
    with ExplorationEngine({"TF": g}, cfg, batched_screen=True) as eng:
        batched = eng.screen(cands)
    with ExplorationEngine({"TF": g}, cfg, batched_screen=False) as eng:
        ref = eng.screen(cands)
    assert [(p.arch, p.objective, p.energy_j, p.delay_s) for p in batched] \
        == [(p.arch, p.objective, p.energy_j, p.delay_s) for p in ref]


def test_screened_run_unchanged_by_batched_screen():
    """run() with screening prunes the same candidates and produces the
    same refined points whichever screening implementation runs."""
    g = transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")
    cfg = DSEConfig(batch=8, sa=SAConfig(iters=40, seed=0))
    cands = _quick_cands(6)
    with ExplorationEngine({"TF": g}, cfg, batched_screen=True) as eng:
        a = eng.run(cands, screen_keep=0.5)
        screen_a = [(p.arch, p.objective) for p in eng.last_screen]
    with ExplorationEngine({"TF": g}, cfg, batched_screen=False) as eng:
        b = eng.run(cands, screen_keep=0.5)
        screen_b = [(p.arch, p.objective) for p in eng.last_screen]
    assert screen_a == screen_b
    assert [(p.arch, p.objective) for p in a] \
        == [(p.arch, p.objective) for p in b]


def test_eval_mapping_archs_refuses_foreign_signature(setup):
    arch, g, groups, init = setup
    ev = Evaluator(arch, g)
    other = arch.replace(glb_kb=arch.glb_kb * 2)
    assert analysis_signature(other) != analysis_signature(arch)
    with pytest.raises(ValueError, match="signature"):
        ev.eval_mapping_archs(init, 8, [other])
    # bandwidth-only siblings are accepted and bit-identical to per-arch
    # scalar evaluation
    sibs = [arch.replace(noc_bw=nb, dram_bw=db)
            for nb in (8.0, 16.0) for db in (64.0, 128.0)]
    E, D = ev.eval_mapping_archs(init, 8, sibs)
    for c, sib in enumerate(sibs):
        r = Evaluator(sib, g).evaluate(init, 8)
        assert r.energy_j == E[c] and r.delay_s == D[c]


# ---------------------------------------------------------------------------
# Pareto sweep + cached CDF
# ---------------------------------------------------------------------------

@SET
@given(vals=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3),
                               st.integers(0, 3)), max_size=40))
def test_pareto_sweep_matches_quadratic_small_ints(vals):
    vals = [tuple(float(x) for x in v) for v in vals]
    assert _pareto_mask_sweep(vals) == _pareto_mask_quadratic(vals)


@SET
@given(vals=st.lists(st.tuples(st.floats(-1e3, 1e3),
                               st.floats(-1e3, 1e3)), max_size=40))
def test_pareto_sweep_matches_quadratic_2d_floats(vals):
    vals = [tuple(v) for v in vals]
    assert _pareto_mask_sweep(vals) == _pareto_mask_quadratic(vals)


def test_group_draw_cdf_cached_and_correct(setup):
    arch, g, groups, init = setup
    a = group_draw_cdf(groups, arch.n_cores)
    b = group_draw_cdf(list(groups), arch.n_cores)
    assert a is b                        # one cached CDF per (sizes, cores)
    assert a[-1] == 1.0
    assert not a.flags.writeable         # shared read-only
    assert np.all(np.diff(a) >= 0)
    other = group_draw_cdf(groups, arch.n_cores + 1)
    assert other is not a


# ---------------------------------------------------------------------------
# batched prefetch builders vs pure scalar builders (raw-stream A/B)
# ---------------------------------------------------------------------------

def test_prefetch_builders_stream_identical_to_scalar():
    """The batched construction path must seal byte-identical contribution
    streams, not merely equal replayed sums: compare all ten GroupAnalysis
    arrays AND the raw flat_idx/flat_vals of every cached piece between a
    prefetch-primed analyzer and a pure scalar one, across workloads with
    expert branches (MoE) and plain transformer deps."""
    from repro.core.analyzer import Analyzer
    from repro.core.workloads import make_workload

    fields = ("core_macs", "edge_bytes", "edge_bytes_amortized",
              "dram_bytes", "dram_bytes_amortized", "core_glb_need",
              "core_in_bytes", "core_out_bytes", "core_time_s",
              "glb_rw_bytes")
    arch = _arch()
    n_pieces = 0
    for g in (make_workload("moe-quick"), _graph()):
        groups = partition_graph(g, arch, 8)
        rng = np.random.default_rng(1234)
        for group in groups:
            for _ in range(2):
                lms = random_lms(group, g, arch.n_cores, arch.n_dram, rng)
                a = Analyzer(arch, g)            # batched-primed
                b = Analyzer(arch, g)            # pure scalar
                a._prefetch_contribs([(group, lms)], 8)
                ra = a.analyze(group, lms, 8)
                rb = b.analyze(group, lms, 8)
                for f in fields:
                    va, vb = getattr(ra, f), getattr(rb, f)
                    if va is None and vb is None:
                        continue
                    assert np.array_equal(va, vb), f
                assert ra.weight_dram_bytes_total \
                    == rb.weight_dram_bytes_total
                for cache_name in ("_layer_cache", "_dep_cache"):
                    ca, cb = getattr(a, cache_name), getattr(b, cache_name)
                    for k in cb:
                        assert k in ca, (cache_name, k)
                        pa, pb = ca[k], cb[k]
                        pa = pa if isinstance(pa, tuple) else (pa,)
                        pb = pb if isinstance(pb, tuple) else (pb,)
                        for xa, xb in zip(pa, pb):
                            assert np.array_equal(xa.flat_idx, xb.flat_idx)
                            assert np.array_equal(xa.flat_vals, xb.flat_vals)
                            assert xa.weight_total == xb.weight_total
                            n_pieces += 1
    assert n_pieces > 0
