"""Analyzer conservation/geometry tests."""

import numpy as np
import pytest

from repro.core.analyzer import Analyzer, d2d_hop_stats, router_grid
from repro.core.encoding import LMS, MS
from repro.core.hw import ArchConfig
from repro.core.workload import Graph, Layer, LayerGroup


def _arch(**kw):
    kw.setdefault("x_cores", 4)
    kw.setdefault("y_cores", 2)
    kw.setdefault("xcut", 2)
    kw.setdefault("ycut", 1)
    return ArchConfig(**kw)


def _two_layer_graph():
    g = Graph("g")
    g.add(Layer(name="a", kind="conv", K=8, H=4, W=4, C=3))
    g.add(Layer(name="b", kind="conv", K=8, H=4, W=4, C=8), ["a"])
    return g


def test_router_grid_d2d_edges():
    arch = _arch()
    grid = router_grid(arch)
    # vertical cut between x=2,3 of cores -> node cols 2|3... plus IO edges
    assert grid.edge_is_d2d.any()
    # all edges between IO column (0) and first core column are d2d
    assert grid.n_edges > 0


def test_same_core_no_traffic():
    """Producer and consumer on the same single core -> zero NoC bytes."""
    arch = _arch()
    g = _two_layer_graph()
    grp = LayerGroup(names=("a", "b"), batch_unit=1)
    # different cores for a and b is required (disjoint CG) — so instead
    # check: traffic from a's core to b's core flows on the path between.
    lms = LMS(ms={
        "a": MS(part=(1, 1, 1, 1), cg=(0,), fd=(1, 1, -1)),
        "b": MS(part=(1, 1, 1, 1), cg=(1,), fd=(-1, 1, 1)),
    })
    an = Analyzer(arch, g).analyze(grp, lms, total_batch=1)
    # dependency a->b is K*H*W bytes
    expected = 8 * 4 * 4
    assert an.core_out_bytes[0] == expected
    assert an.core_in_bytes[1] >= expected


def test_k_partition_multicast_counts_once():
    """Consumer K-partitioned: both parts need a's full ofmap -> multicast
    tree must carry the data once on shared edges."""
    arch = _arch()
    g = _two_layer_graph()
    grp = LayerGroup(names=("a", "b"), batch_unit=1)
    lms_multi = LMS(ms={
        "a": MS(part=(1, 1, 1, 1), cg=(0,), fd=(1, 1, -1)),
        "b": MS(part=(1, 1, 1, 2), cg=(1, 2), fd=(-1, 1, 1)),
    })
    an = Analyzer(arch, g).analyze(grp, lms_multi, total_batch=1)
    # core0 -> core1 -> core2 is one XY path; shared first hop counted once
    vol = 8 * 4 * 4
    assert an.core_out_bytes[0] == vol          # multicast: one emission
    assert an.core_in_bytes[1] == vol
    assert an.core_in_bytes[2] == vol


def test_d2d_bytes_when_crossing_cut():
    arch = _arch()          # cut between core x=1 and x=2
    g = _two_layer_graph()
    grp = LayerGroup(names=("a", "b"), batch_unit=1)
    # core 0 (x=0) -> core 3 (x=3) crosses the cut
    lms = LMS(ms={
        "a": MS(part=(1, 1, 1, 1), cg=(0,), fd=(1, 1, -1)),
        "b": MS(part=(1, 1, 1, 1), cg=(3,), fd=(-1, 1, 1)),
    })
    an = Analyzer(arch, g).analyze(grp, lms, total_batch=1)
    assert an.d2d_bytes >= 8 * 4 * 4


def test_compute_conservation():
    """Sum of per-core MACs equals the layer total regardless of mapping."""
    arch = _arch()
    g = _two_layer_graph()
    grp = LayerGroup(names=("a", "b"), batch_unit=2)
    rng = np.random.default_rng(3)
    from repro.core.encoding import random_lms
    totals = []
    for seed in range(5):
        lms = random_lms(grp, g, arch.n_cores, arch.n_dram,
                         np.random.default_rng(seed))
        an = Analyzer(arch, g).analyze(grp, lms, total_batch=2)
        totals.append(an.core_macs.sum())
    expected = g.layers["a"].macs(2) + g.layers["b"].macs(2)
    for t in totals:
        assert abs(t - expected) / expected < 1e-6


def test_interleaved_dram_balances():
    arch = _arch(n_dram=2)
    g = Graph("g1")
    g.add(Layer(name="a", kind="conv", K=16, H=8, W=8, C=3))
    grp = LayerGroup(names=("a",), batch_unit=1)
    lms0 = LMS(ms={"a": MS(part=(1, 1, 1, 1), cg=(0,), fd=(1, 1, 1))})
    lmsI = LMS(ms={"a": MS(part=(1, 1, 1, 1), cg=(0,), fd=(0, 0, 0))})
    an0 = Analyzer(arch, g).analyze(grp, lms0, total_batch=1)
    anI = Analyzer(arch, g).analyze(grp, lmsI, total_batch=1)
    # pinned: all fmap traffic on DRAM 0; interleaved: split evenly
    assert an0.dram_bytes[1] == 0
    assert abs(anI.dram_bytes[0] - anI.dram_bytes[1]) < 1e-9
    assert np.isclose(an0.dram_bytes.sum(), anI.dram_bytes.sum())
