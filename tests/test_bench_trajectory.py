"""bench_dse/v2 trajectory document: pure projection/migration functions,
in-place v1 migration on write, append-only history, and the committed
lockstep-speedup floor check."""

import json

import pytest

from benchmarks.run import (LOCKSTEP_SPEEDUP_FLOOR, check_floor,
                            make_trajectory_entry, migrate_bench_doc)


def _v1_doc():
    return {
        "schema": "bench_dse/v1",
        "grid": "table1 --quick (72 TOPS, 12 candidates)",
        "screening": {"batched_cands_per_s": 45.0, "batched_s": 0.27},
        "lockstep_sa": {"serial_iters_per_s": 320.0,
                        "lockstep_iters_per_s": 360.0,
                        "fused_iters_per_s": 86.0,
                        "speedup": 1.125},
        "sweep_n4": {"wall_s": 2.6},
        "vs_pr4": {"sa_chain_n4_speedup": 1.53},
        "provenance": {"cpu_count": 1},
    }


def test_make_trajectory_entry_projects_headline_figures():
    e = make_trajectory_entry(_v1_doc(), commit="abc1234",
                              date="2026-08-08T00:00:00Z")
    assert e["commit"] == "abc1234"
    assert e["date"] == "2026-08-08T00:00:00Z"
    assert e["cpus"] == 1
    assert e["screening_cands_per_s"] == 45.0
    assert e["serial_iters_per_s"] == 320.0
    assert e["lockstep_iters_per_s"] == 360.0
    assert e["fused_iters_per_s"] == 86.0
    assert e["lockstep_speedup"] == 1.125
    assert e["sa_chain_n4_speedup_vs_pr4"] == 1.53
    assert e["sweep_n4_wall_s"] == 2.6


def test_make_trajectory_entry_tolerates_missing_sections():
    e = make_trajectory_entry({}, commit="x", date="d")
    assert e["cpus"] is None
    assert e["lockstep_iters_per_s"] is None


def test_migrate_v1_wraps_snapshot_as_first_row():
    doc = migrate_bench_doc(_v1_doc())
    assert doc["schema"] == "bench_dse/v2"
    assert len(doc["trajectory"]) == 1
    row = doc["trajectory"][0]
    assert row["commit"] == "pre-v2"            # v1 recorded no commit
    assert row["lockstep_iters_per_s"] == 360.0
    # snapshot fields survive alongside the trajectory
    assert doc["lockstep_sa"]["speedup"] == 1.125


def test_migrate_v2_passes_through():
    v2 = migrate_bench_doc(_v1_doc())
    v2["trajectory"].append(
        make_trajectory_entry(_v1_doc(), commit="def", date="later"))
    again = migrate_bench_doc(v2)
    assert again is v2
    assert len(again["trajectory"]) == 2        # append-only, no rewrap


def test_check_floor_passes_and_fails(tmp_path, capsys):
    doc = migrate_bench_doc(_v1_doc())
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(doc))
    check_floor(ok)                             # 1.125 >= floor
    assert "OK" in capsys.readouterr().out
    doc["lockstep_sa"]["speedup"] = LOCKSTEP_SPEEDUP_FLOOR - 0.05
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    with pytest.raises(SystemExit, match="below|FAIL"):
        check_floor(bad)


def test_committed_bench_json_is_v2_with_trajectory():
    """The checked-in BENCH_dse.json must carry the v2 trajectory and
    container provenance (satellites of the fused-pass PR)."""
    from pathlib import Path
    path = Path(__file__).resolve().parent.parent / "BENCH_dse.json"
    doc = json.loads(path.read_text())
    assert doc["schema"] == "bench_dse/v2"
    assert doc["trajectory"], "append-only trajectory must be non-empty"
    assert {"commit", "date", "cpus"} <= set(doc["trajectory"][-1])
    prov = doc["provenance"]
    assert prov["cpu_count"] >= 1
    assert prov["jax"]
