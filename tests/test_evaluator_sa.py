"""Evaluator monotonicity + SA engine behaviour + MC evaluator claims."""

import numpy as np
import pytest

from repro.core.evaluator import Evaluator
from repro.core.graph_partition import partition_graph, pick_batch_unit
from repro.core.hw import ArchConfig, gemini_arch_72t, simba_arch
from repro.core.mc import evaluate_mc
from repro.core.sa import SAConfig, sa_optimize
from repro.core.tangram import tangram_map
from repro.core.workloads import transformer


def _tf_small():
    return transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")


def test_evaluate_positive_and_decomposed():
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    ev = Evaluator(arch, g)
    r = ev.evaluate(tangram_map(groups, g, arch), 8)
    assert r.delay_s > 0 and r.energy_j > 0
    for ge in r.groups:
        assert ge.energy_j == pytest.approx(sum(ge.energy_breakdown.values()))
        assert ge.bottleneck in ("compute", "noc", "d2d", "dram")


def test_more_noc_bw_not_slower():
    g = _tf_small()
    arch_lo = simba_arch().replace(noc_bw=8.0, d2d_bw=4.0)
    arch_hi = simba_arch().replace(noc_bw=64.0, d2d_bw=32.0)
    d = {}
    for name, arch in (("lo", arch_lo), ("hi", arch_hi)):
        groups = partition_graph(g, arch, 8)
        ev = Evaluator(arch, g)
        d[name] = ev.evaluate(tangram_map(groups, g, arch), 8).delay_s
    assert d["hi"] <= d["lo"] * 1.01


def test_batch_scaling_delay():
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)   # batch_unit <= 8
    ev = Evaluator(arch, g)
    m = tangram_map(groups, g, arch)
    d8 = ev.evaluate(m, 8).delay_s
    d512 = ev.evaluate(m, 512).delay_s     # 64x the passes
    assert d512 > d8 * 3                   # fill/drain damps small ratios


def test_sa_improves_over_tmap():
    arch = simba_arch()
    g = transformer(n_layers=3, d_model=256, d_ff=512, seq=128, name="tf-m")
    groups = partition_graph(g, arch, 16)
    ev = Evaluator(arch, g)
    init = tangram_map(groups, g, arch)
    base = ev.evaluate(init, 16)
    res = sa_optimize(g, arch, groups, 16,
                      SAConfig(iters=800, seed=0), init=init, evaluator=ev)
    assert res.cost <= base.cost() * 1.0001
    # the returned mapping is valid
    for grp, lms in res.mapping:
        lms.validate(grp, g, arch.n_cores, arch.n_dram)


def test_sa_deterministic_by_seed():
    arch = simba_arch()
    g = _tf_small()
    groups = partition_graph(g, arch, 8)
    r1 = sa_optimize(g, arch, groups, 8, SAConfig(iters=200, seed=7))
    r2 = sa_optimize(g, arch, groups, 8, SAConfig(iters=200, seed=7))
    assert r1.cost == r2.cost


def test_graph_partition_covers_in_order():
    arch = simba_arch()
    g = transformer(n_layers=2, d_model=128, d_ff=256, seq=64)
    groups = partition_graph(g, arch, 16)
    flat = [n for grp in groups for n in grp.names]
    assert flat == g.topo_order()
    for grp in groups:
        assert 1 <= grp.batch_unit <= 64


def test_pick_batch_unit_fits_glb():
    arch = simba_arch()
    g = _tf_small()
    names = list(g.layers)[:4]
    bu = pick_batch_unit(g, names, arch, 64)
    glb_total = arch.core_glb_bytes * arch.n_cores
    weights = sum(g.layers[n].weight_bytes() for n in names)
    fmaps = sum(g.layers[n].ofmap_bytes(bu) * 2 for n in names)
    assert bu == 1 or weights + fmaps * 2 <= glb_total


# ---------------------------------------------------------------------------
# Monetary cost (paper Sec. V-C / VII-A)
# ---------------------------------------------------------------------------

def test_mc_simba_d2d_share():
    mc = evaluate_mc(simba_arch())
    assert 0.30 <= mc.d2d_area_fraction <= 0.55      # "nearly 40%" in paper


def test_mc_garch_close_to_sarch():
    s = evaluate_mc(simba_arch()).total
    gm = evaluate_mc(gemini_arch_72t()).total
    assert abs(gm - s) / s < 0.35                    # paper: +14.3% (G+DSE)


def test_mc_overly_fine_partition_worse():
    base = ArchConfig(x_cores=6, y_cores=6, xcut=2, ycut=1)
    fine = ArchConfig(x_cores=6, y_cores=6, xcut=6, ycut=6)
    assert evaluate_mc(fine).total > evaluate_mc(base).total


def test_mc_yield_model():
    """Bigger dies must cost super-linearly more silicon."""
    small = ArchConfig(x_cores=4, y_cores=4, xcut=2, ycut=2, glb_kb=1024)
    big = ArchConfig(x_cores=4, y_cores=4, xcut=1, ycut=1, glb_kb=1024)
    mcs, mcb = evaluate_mc(small), evaluate_mc(big)
    # same logic area; the monolithic die pays the yield tax on silicon
    per_mm2_small = mcs.silicon / mcs.total_silicon_area
    per_mm2_big = mcb.silicon / mcb.total_silicon_area
    assert per_mm2_big > per_mm2_small
