"""Model correctness: decode consistency (prefill + step == full forward),
MoE routing sanity, per-family smoke at reduced configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import model_api

KEY = jax.random.PRNGKey(0)


def _f32(cfg):
    return cfg.replace(compute_dtype="float32")


@pytest.mark.parametrize("arch", sorted(all_archs()))
def test_arch_smoke_train_step(arch):
    """Assigned-architecture smoke: one fwd/train step, shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    api = model_api(cfg)
    params, axes = api.init_params(KEY)
    B, S = 2, 32
    if cfg.frontend in ("patch", "audio"):
        batch = {"embeds": jnp.ones((B, S, cfg.d_model), jnp.float32) * 0.01,
                 "labels": jnp.zeros((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["tokens"] = jnp.zeros((B, S), jnp.int32)
    else:
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "labels": jnp.zeros((B, S), jnp.int32)}
    loss, metrics = jax.jit(api.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    # grads flow and are finite
    g = jax.grad(lambda p: api.loss_fn(p, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert leaves and all(np.isfinite(np.asarray(x)).all() for x in leaves)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-0.6b", "mamba2-370m",
                                  "zamba2-1.2b", "phi3.5-moe-42b-a6.6b"])
def test_decode_matches_full_forward(arch):
    """prefill(prompt) + decode steps produce the same logits as one full
    forward pass — the core serving-correctness invariant."""
    from repro.models import lm
    cfg = _f32(get_config(arch).reduced())
    if cfg.family == "moe":
        # capacity drops depend on how many tokens route together; make
        # capacity ample so teacher-forced and incremental paths agree
        # (train/serve routing mismatch is inherent to capacity MoE).
        cfg = cfg.replace(capacity_factor=8.0)
    api = model_api(cfg)
    params, _ = api.init_params(KEY)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    logits_full, _, _ = lm.forward(cfg, params, {"tokens": toks},
                                   mode="train")
    # prefill on the first 8, then decode 4 steps
    cache, _ = api.init_cache(B, S + 4, S)
    lg, cache = api.prefill(params, {"tokens": toks[:, :8]}, cache)
    # KV caches are bf16 by design (serving memory); tolerance covers the
    # cache-quantization delta, not logic error (verified ~1e-6 exact when
    # the cache dtype is f32).
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, 7]),
                               atol=2e-2, rtol=2e-2)
    for t in range(8, S):
        lg, cache = api.decode_step(params, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]),
            atol=2e-2, rtol=2e-2)


def test_encdec_decode_consistency():
    from repro.models import encdec
    cfg = _f32(get_config("whisper-small").reduced())
    api = model_api(cfg)
    params, _ = api.init_params(KEY)
    B, Se, Sd = 1, 16, 8
    emb = jax.random.normal(jax.random.PRNGKey(2), (B, Se, cfg.d_model)) * 0.02
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, Sd), 0, cfg.vocab)
    enc = encdec.encode(cfg, params, emb)
    logits_full, _ = encdec.decode(cfg, params, toks, enc)
    cache, _ = api.init_cache(B, Sd + 2, Se)
    lg, cache = api.prefill(params, {"embeds": emb, "tokens": toks[:, :4]},
                            cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, 3]),
                               atol=3e-3, rtol=3e-3)
    for t in range(4, Sd):
        lg, cache = api.decode_step(params, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, t]),
                                   atol=5e-3, rtol=5e-3)


def test_moe_aux_loss_and_balance():
    from repro.nn.moe import init_moe, moe_block
    p, _ = init_moe(jax.random.PRNGKey(0), 32, 64, n_experts=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_block(p, x, n_experts=4, top_k=2,
                         compute_dtype=jnp.float32)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # aux loss ~ n_experts * sum(f*P); for top-2-of-4 it's >= 2 (lower bound
    # at perfect balance is E * k / E = k)
    assert float(aux) >= 1.0


def test_moe_capacity_drops_renormalize():
    from repro.nn.moe import init_moe, moe_block
    p, _ = init_moe(jax.random.PRNGKey(0), 16, 32, n_experts=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    out, _ = moe_block(p, x, n_experts=2, top_k=1, capacity_factor=0.25,
                       compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(out)).all()


def test_param_count_analytic_matches_init():
    from repro.nn.params import count_params
    for arch in ("smollm-135m", "qwen3-0.6b"):
        cfg = get_config(arch)
        api = model_api(cfg)
        structs = jax.eval_shape(lambda k: api.init_params(k)[0],
                                 jax.ShapeDtypeStruct((2,), jnp.uint32))
        actual = count_params(structs)
        analytic = cfg.param_count()
        # padded vocab inflates actual slightly; norms excluded analytically
        assert abs(actual - analytic) / analytic < 0.05, (arch, actual, analytic)


def test_full_size_param_counts():
    """The assigned archs hit their nominal sizes (sanity vs the table)."""
    approx = {
        "qwen1.5-110b": 110e9, "qwen3-32b": 32e9, "llava-next-34b": 34e9,
        "phi3.5-moe-42b-a6.6b": 42e9, "smollm-135m": 135e6,
        "mamba2-370m": 370e6,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.55 * target < n < 1.6 * target, (arch, n, target)
