"""Realization-loop tests: checkpoint -> MeshPlan round-trip, plan
validation, Pallas-vs-jnp parity of a realized stage (subprocess with
forced host devices), and the calibration overlay invariants."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core.bridge import MeshPlan, StagePlan, lms_to_plan
from repro.core.dse import DSEConfig, run_dse
from repro.core.evaluator import Evaluator
from repro.core.explore import mapping_to_jsonable
from repro.core.hw import ArchConfig, TECH_12NM
from repro.core.sa import SAConfig
from repro.core.tangram import tangram_map
from repro.core.workload import LayerGroup
from repro.core.workloads import transformer
from repro.realize.calibrate import (TechOverlay, calibrated_candidates,
                                     fit_overlay, load_overlay, save_overlay)
from repro.realize.plan import (graph_from_spec, load_realize_candidates,
                                plans_for, validate_plan)

REPO = Path(__file__).resolve().parent.parent


def _arch(xcut: int = 1) -> ArchConfig:
    return ArchConfig(x_cores=2, y_cores=2, xcut=xcut, ycut=1, noc_bw=32.0,
                      d2d_bw=16.0, dram_bw=64.0, glb_kb=512,
                      macs_per_core=1024)


def _graph():
    return transformer(n_layers=1, d_model=64, d_ff=128, seq=32, name="tf-t")


def _keep_ckpt(tmp_path, g, cands):
    cfg = DSEConfig(batch=4, sa=SAConfig(iters=40, seed=0),
                    keep_mappings=True)
    ck = tmp_path / "rt.ckpt.jsonl"
    pts = run_dse(cands, {"TF": g}, cfg, checkpoint=ck)
    return ck, cfg, pts


# ---------------------------------------------------------------------------
# checkpoint -> MeshPlan round-trip
# ---------------------------------------------------------------------------

def test_checkpoint_to_plan_roundtrip(tmp_path):
    g = _graph()
    cands = [_arch(1), _arch(2)]
    ck, cfg, pts = _keep_ckpt(tmp_path, g, cands)
    rcands = load_realize_candidates(ck, {"TF": g}, top=0, verbose=False)
    assert len(rcands) == 2
    # loaded mappings are the exact serialized ones from the sweep
    by_label = {p.arch.label(): p for p in pts}
    for rc in rcands:
        src = by_label[rc.arch.label()]
        assert mapping_to_jsonable(rc.mapping) == \
            mapping_to_jsonable(src.mappings["TF"])
        plan = rc.lower()
        # the lowered plan mirrors the mapping group-for-group
        assert len(plan.stages) == len(rc.mapping)
        for st, (grp, lms) in zip(plan.stages, rc.mapping):
            assert st.layers == grp.names
            assert set(st.devices) == set(lms.cores_used())
            for name in grp.names:
                assert st.parts[name] == lms.ms[name].part
                assert st.cgs[name] == lms.ms[name].cg
        assert plan.batch_unit == rc.mapping[-1][0].batch_unit
        validate_plan(plan, n_devices=rc.arch.n_cores, arch=rc.arch)


def test_load_rejects_wrong_graph(tmp_path):
    g = _graph()
    ck, _, _ = _keep_ckpt(tmp_path, g, [_arch(1)])
    other = transformer(n_layers=1, d_model=32, d_ff=64, seq=32, name="tf-t")
    with pytest.raises(ValueError, match="content-match"):
        load_realize_candidates(ck, {"TF": other}, verbose=False)


def test_load_refuses_metrics_only(tmp_path):
    g = _graph()
    cfg = DSEConfig(batch=4, sa=SAConfig(iters=30, seed=0))  # no mappings
    ck = tmp_path / "nomap.ckpt.jsonl"
    run_dse([_arch(1)], {"TF": g}, cfg, checkpoint=ck)
    with pytest.raises(ValueError, match="keep_mappings"):
        load_realize_candidates(ck, {"TF": g}, verbose=False)


def test_graph_from_spec():
    g = graph_from_spec("transformer:n_layers=1,d_model=64,d_ff=128,"
                        "seq=32,name=tf-t")
    assert g.layers.keys() == _graph().layers.keys()
    with pytest.raises(ValueError, match="unknown workload spec"):
        graph_from_spec("nonsense")


# ---------------------------------------------------------------------------
# plan validation
# ---------------------------------------------------------------------------

def test_validate_plan_rejects_device_mismatch():
    g = _graph()
    arch = _arch(1)
    groups = [LayerGroup(names=tuple(g.topo_order()), batch_unit=2)]
    # tangram needs >= 1 core per layer: use a wider arch for the mapping
    wide = ArchConfig(x_cores=4, y_cores=4, noc_bw=32.0, d2d_bw=16.0,
                      dram_bw=64.0, glb_kb=512, macs_per_core=1024)
    mapping = tangram_map(groups, g, wide)
    plan = lms_to_plan(mapping)
    validate_plan(plan, n_devices=16, arch=wide)
    with pytest.raises(ValueError, match="devices"):
        validate_plan(plan, n_devices=4)           # pool too small
    with pytest.raises(ValueError, match="corrupt"):
        validate_plan(plan, n_devices=16, arch=arch)   # 4-core arch
    # structural damage: Part product != |CG|
    bad = MeshPlan(stages=[StagePlan(layers=("l",), devices=(0, 1),
                                     parts={"l": (1, 1, 1, 1)},
                                     cgs={"l": (0, 1)})], batch_unit=1)
    with pytest.raises(ValueError, match="product"):
        validate_plan(bad, n_devices=4)


# ---------------------------------------------------------------------------
# realized stage parity + measurement (subprocess: forced host devices)
# ---------------------------------------------------------------------------

def _run_sub(code: str, n_devices: int = 12, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.splitlines()[-1])


def test_realized_stage_pallas_vs_oracle_parity():
    code = textwrap.dedent("""
        import json
        import numpy as np
        from repro.core.bridge import lms_to_plan
        from repro.core.hw import ArchConfig
        from repro.core.tangram import tangram_map
        from repro.core.workload import LayerGroup
        from repro.core.workloads import transformer
        from repro.realize.measure import measure_candidate
        from repro.realize.plan import RealizeCandidate
        from repro.realize.program import build_program

        arch = ArchConfig(x_cores=4, y_cores=3, xcut=2, ycut=1, noc_bw=32,
                          d2d_bw=16, dram_bw=64, glb_kb=1024,
                          macs_per_core=1024)
        g = transformer(n_layers=1, d_model=64, d_ff=128, seq=32,
                        name="tf-par")
        groups = [LayerGroup(names=tuple(g.topo_order()), batch_unit=2)]
        mapping = tangram_map(groups, g, arch)
        plan = lms_to_plan(mapping)
        out = {}
        runs = {}
        for use_pallas in (True, False):
            prog = build_program(g, plan, use_pallas=use_pallas)
            prog.compile_all()
            runs[use_pallas] = prog.execute(seed=0)
            if use_pallas:
                routes = prog.stages[0].routes
                out["has_flash"] = any(r.startswith("flash:")
                                       for r in routes.values())
                cand = RealizeCandidate(
                    key="k", workload="TF", arch=arch, mapping=mapping,
                    graph=g, energy_j=1.0, delay_s=1.0)
                rep = measure_candidate(cand, prog, execute=False)
                st = rep.stages[0]
                out["flops"] = st.flops
                out["pred_flops"] = st.pred_flops
                out["hbm"] = st.hbm_bytes
                out["pred_dram"] = st.pred_dram_bytes
                out["ratios"] = st.ratios()
        errs = []
        for name, a in runs[True]["outputs"].items():
            b = runs[False]["outputs"][name]
            errs.append(float(np.abs(np.asarray(a) - np.asarray(b)).max()
                              / (np.abs(np.asarray(b)).max() + 1e-9)))
        out["max_rel_err"] = max(errs)
        print(json.dumps(out))
    """)
    rec = _run_sub(code)
    # the realized stage must actually exercise the flash kernel route
    assert rec["has_flash"]
    assert rec["max_rel_err"] < 2e-4
    # measured/predicted of the same stage are within calibration range
    assert rec["flops"] > 0 and rec["pred_flops"] > 0
    assert 0.2 < rec["ratios"]["flops"] < 20.0
    assert rec["hbm"] > 0 and rec["pred_dram"] > 0


def test_realize_driver_end_to_end(tmp_path):
    """checkpoint -> CLI driver (--top 2 --calibrate) -> report + overlay."""
    g = _graph()
    ck, _, _ = _keep_ckpt(tmp_path, g, [_arch(1), _arch(2)])
    out = tmp_path / "realize.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.realize",
           "--ckpt", str(ck),
           "--workload",
           "TF=transformer:n_layers=1,d_model=64,d_ff=128,seq=32,name=tf-t",
           "--top", "2", "--calibrate", "--host-devices", "8",
           "--out", str(out)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    recs = [json.loads(l) for l in out.read_text().splitlines()
            if "_key" in l]
    assert len(recs) == 2
    for rec in recs:
        assert rec["totals"]["flops"] > 0
        assert rec["stages"]
    overlay = load_overlay(out.with_suffix(".overlay.json"))
    assert overlay.n_stages > 0
    # resumed run: no re-measurement, same record count
    r2 = subprocess.run(cmd, capture_output=True, text=True, timeout=300,
                        env=env)
    assert r2.returncode == 0
    assert r2.stdout.count("resumed from") == 2


# ---------------------------------------------------------------------------
# calibration invariants
# ---------------------------------------------------------------------------

def _synthetic_report(ratio: float):
    from repro.realize.measure import RealizationReport, StageReport
    st = StageReport(index=0, layers=("l",), n_devices=2, routes={},
                     flops=2.0e6, pred_flops=1.0e6,
                     hbm_bytes=ratio * 1e6, pred_dram_bytes=1e6,
                     ici_bytes=ratio * 1e5, pred_noc_bytes=1e5,
                     dci_bytes=ratio * 1e4, pred_d2d_bytes=1e4)
    return RealizationReport(key="k", workload="TF", arch_label="a",
                             tech=TECH_12NM.name, batch_unit=1, stages=[st])


def test_overlay_identity_is_bitwise_noop():
    overlay = TechOverlay()
    assert overlay.is_identity()
    assert overlay.apply(TECH_12NM) is TECH_12NM
    arch = _arch(2)
    assert overlay.apply_arch(arch) is arch
    cands = [_arch(1), _arch(2)]
    assert all(a is b for a, b in
               zip(calibrated_candidates(cands, overlay), cands))
    # run_dse under the identity overlay is bit-identical to baseline
    g = _graph()
    cfg = DSEConfig(batch=4, sa=SAConfig(iters=30, seed=0))
    base = run_dse(cands, {"TF": g}, cfg)
    cal = run_dse(calibrated_candidates(cands, overlay), {"TF": g}, cfg)
    assert [(p.objective, p.energy_j, p.delay_s) for p in base] == \
        [(p.objective, p.energy_j, p.delay_s) for p in cal]


def test_overlay_shifts_evaluator_toward_measurement():
    """measured > predicted traffic => calibrated evaluator reports MORE
    energy for the same mapping (and vice versa)."""
    g = _graph()
    wide = ArchConfig(x_cores=4, y_cores=4, xcut=2, ycut=1, noc_bw=32.0,
                      d2d_bw=16.0, dram_bw=64.0, glb_kb=512,
                      macs_per_core=1024)
    groups = [LayerGroup(names=tuple(g.topo_order()), batch_unit=2)]
    mapping = tangram_map(groups, g, wide)
    base_e = Evaluator(wide, g).evaluate(mapping, 4).energy_j
    for ratio, direction in ((3.0, 1), (0.3, -1)):
        overlay = fit_overlay([_synthetic_report(ratio)])
        assert not overlay.is_identity()
        np.testing.assert_allclose(
            [overlay.f_dram, overlay.f_noc, overlay.f_d2d],
            [ratio] * 3, rtol=1e-9)
        cal_arch = overlay.apply_arch(wide)
        assert cal_arch.tech.name.startswith(TECH_12NM.name + "+cal")
        cal_e = Evaluator(cal_arch, g).evaluate(mapping, 4).energy_j
        assert direction * (cal_e - base_e) > 0
    # different overlays must yield differently-named Techs: checkpoints
    # identify techs by name, so a collision would let a sweep calibrated
    # under one overlay resume under another's constants
    a = fit_overlay([_synthetic_report(3.0)]).apply(TECH_12NM)
    b = fit_overlay([_synthetic_report(0.3)]).apply(TECH_12NM)
    assert a.name != b.name
    # fit is clamped against degenerate stages
    wild = fit_overlay([_synthetic_report(1e6)])
    assert wild.f_dram == 10.0


def test_overlay_json_roundtrip(tmp_path):
    overlay = fit_overlay([_synthetic_report(2.5)], source="test")
    p = save_overlay(overlay, tmp_path / "ov.json")
    back = load_overlay(p)
    assert back == overlay


def test_calibrated_sweep_resumable(tmp_path):
    """A non-identity overlay registers its Tech: calibrated checkpoints
    must survive resume (arch_from_dict refuses unknown tech names)."""
    overlay = fit_overlay([_synthetic_report(2.0)])
    g = _graph()
    cands = calibrated_candidates([_arch(1)], overlay)
    cfg = DSEConfig(batch=4, sa=SAConfig(iters=30, seed=0))
    ck = tmp_path / "cal.ckpt.jsonl"
    first = run_dse(cands, {"TF": g}, cfg, checkpoint=ck)
    again = run_dse(cands, {"TF": g}, cfg, checkpoint=ck)
    assert [p.objective for p in first] == [p.objective for p in again]
