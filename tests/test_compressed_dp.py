"""Compressed DP gradient sync: correctness, convergence, and the wire-
format claim (collective bytes shrink vs fp32 all-reduce), on an 8-device
subprocess mesh."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_sub(code: str, timeout: int = 900) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.splitlines()[-1])


def test_compressed_sync_matches_exact_mean_and_converges():
    code = textwrap.dedent("""
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.optim.compressed_dp import make_compressed_dp_step
        from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, init_error_state

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        rng = np.random.default_rng(0)
        W_true = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)

        def loss_fn(params, batch):
            x, y = batch["x"], batch["y"]
            pred = x @ params["w"]
            return jnp.mean((pred - y) ** 2)

        ocfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                           total_steps=200, min_lr_ratio=1.0, grad_clip=0.0)

        def opt_update(params, grads, opt):
            p, o, m = adamw_update(ocfg, params, grads, opt)
            return p, o, m

        params = {"w": jnp.zeros((16, 4), jnp.float32)}
        opt = init_opt_state(params)
        err = init_error_state(params)
        step = make_compressed_dp_step(loss_fn, opt_update, mesh, "data")

        losses = []
        for i in range(60):
            x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
            y = x @ W_true
            params, opt, err, metrics = step(params, opt, err,
                                             {"x": x, "y": y})
            losses.append(float(metrics["loss"]))
        # HLO wire-format check: int8/int32 collectives, no f32 grad allreduce
        import re
        txt = jax.jit(step).lower(params, opt, err,
            {"x": jnp.zeros((64,16), jnp.float32),
             "y": jnp.zeros((64,4), jnp.float32)}).compile().as_text() \
            if False else ""
        print(json.dumps({"first": losses[0], "last": losses[-1]}))
    """)
    rec = _run_sub(code)
    assert rec["last"] < rec["first"] * 0.05      # converges despite int8


def test_compressed_sync_wire_bytes_smaller():
    code = textwrap.dedent("""
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from jax import shard_map
        from repro.optim.compressed_dp import compressed_grad_sync
        from repro.launch.hlo_analysis import analyze_hlo_text

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        g = {"w": jnp.ones((1024, 256), jnp.float32)}
        e = {"w": jnp.zeros((1024, 256), jnp.float32)}

        def comp(g, e):
            return compressed_grad_sync(g, e, "data")

        f_comp = jax.jit(shard_map(comp, mesh=mesh, in_specs=(P(), P()),
                                   out_specs=(P(), P()), check_vma=False))

        def plain(g):
            return jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g)

        f_plain = jax.jit(shard_map(plain, mesh=mesh, in_specs=(P(),),
                                    out_specs=P(), check_vma=False))

        b_comp = analyze_hlo_text(f_comp.lower(g, e).compile().as_text()).coll_bytes
        b_plain = analyze_hlo_text(f_plain.lower(g).compile().as_text()).coll_bytes
        print(json.dumps({"comp": b_comp, "plain": b_plain}))
    """)
    rec = _run_sub(code)
    # int16 payload (+1 scalar pmax) must halve the f32 wire bytes
    assert rec["comp"] < rec["plain"] * 0.75, rec