"""Telemetry layer: bit-identity with tracing on, worker metric
aggregation, heartbeat survival across resume/merge, report golden
output, vlog verbosity, provenance override."""

import json
import os
from pathlib import Path

import pytest

from repro import obs
from repro.core.dse import DSEConfig, grid_candidates, run_dse
from repro.core.explore import (ExplorationEngine, ResumableSweep,
                                merge_checkpoints)
from repro.core.sa import SAConfig
from repro.core.workloads import transformer
from repro.obs.manifest import GIT_COMMIT_ENV, git_head
from repro.obs.report import parse_heartbeats, render_report, shard_progress

DATA = Path(__file__).parent / "data" / "obs_mini"


def _tf_small():
    return transformer(n_layers=2, d_model=128, d_ff=256, seq=64, name="tf-s")


def _grid(n=4):
    cands = grid_candidates(
        72.0, mac_options=(512, 1024), cut_options=(1, 2),
        dram_per_tops=(2.0,), noc_options=(16,), d2d_ratio=(0.5,),
        glb_options=(1024,))
    return cands[:n]


def _cfg(iters=40, seed=3):
    return DSEConfig(batch=8, sa=SAConfig(iters=iters, seed=seed))


def _sig(points):
    return [(p.arch, p.objective, p.energy_j, p.delay_s) for p in points]


@pytest.fixture
def obs_dir(tmp_path):
    """Enable tracing into a temp run dir; always restore global state."""
    d = tmp_path / "obs"
    obs.enable(d)
    yield d
    obs.disable()
    obs.metrics.reset()


# ---------------------------------------------------------------------------
# Bit-identity: tracing on == tracing off
# ---------------------------------------------------------------------------

def test_run_dse_bit_identical_with_tracing(tmp_path):
    g = _tf_small()
    cands = _grid()
    cfg = _cfg()
    off = run_dse(cands, {"TF": g}, cfg)
    d = tmp_path / "obs"
    obs.enable(d)
    try:
        on = run_dse(cands, {"TF": g}, cfg, n_workers=2)
    finally:
        obs.disable()
        obs.metrics.reset()
    assert _sig(off) == _sig(on)
    # artifacts exist and every trace line is valid JSON
    assert (d / "manifest.json").exists()
    assert (d / "metrics.json").exists()
    traces = sorted(d.glob("trace-*.jsonl"))
    assert traces
    for tf in traces:
        for line in tf.read_text().splitlines():
            json.loads(line)
    man = json.loads((d / "manifest.json").read_text())
    assert man["schema"] == "obs_manifest/v1"
    assert man["seed"] == cfg.sa.seed
    m = json.loads((d / "metrics.json").read_text())
    assert m["counters"]["engine.tasks"] == len(off)   # one workload


def test_sharded_sweep_bit_identical_with_tracing(tmp_path, obs_dir):
    g = _tf_small()
    cands = _grid()
    cfg = _cfg()
    obs.disable()
    full = run_dse(cands, {"TF": g}, cfg)
    obs.enable(obs_dir)
    shards = []
    for i in range(2):
        ck = tmp_path / f"shard{i}.jsonl"
        run_dse(cands, {"TF": g}, cfg, shard=(i, 2), checkpoint=ck)
        shards.append(ck)
    merged = tmp_path / "merged.jsonl"
    merge_checkpoints(shards, merged)
    resumed = run_dse(cands, {"TF": g}, cfg, checkpoint=merged)
    assert _sig(full) == _sig(resumed)


def test_disabled_metrics_are_noops():
    assert not obs.enabled()
    c = obs.metrics.counter("test.noop_counter")
    v0 = c.value
    c.inc()
    c.inc(5)
    assert c.value == v0
    h = obs.metrics.histogram("test.noop_hist")
    h.observe(1.0)
    assert h.n == 0
    g = obs.metrics.gauge("test.noop_gauge")
    g.set(3.0)
    assert g.value is None


# ---------------------------------------------------------------------------
# Worker metric aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_workers", [1, 4])
def test_worker_metrics_aggregate_across_pool(tmp_path, n_workers):
    g = _tf_small()
    cands = _grid()
    cfg = _cfg()
    serial = run_dse(cands, {"TF": g}, cfg)
    d = tmp_path / f"obs-w{n_workers}"
    obs.enable(d)
    try:
        pts = run_dse(cands, {"TF": g}, cfg, n_workers=n_workers)
        snap = obs.metrics.snapshot()
    finally:
        obs.disable()
        obs.metrics.reset()
    assert _sig(pts) == _sig(serial)
    n_tasks = len(cands) * 1          # one workload
    assert snap["counters"]["engine.tasks"] == n_tasks
    # SA stats travelled back from the workers (one SA run per task)
    assert snap["counters"]["sa.runs"] == n_tasks
    assert snap["counters"]["sa.proposed"] > 0
    # task wall-time histogram saw every task exactly once
    assert snap["histograms"]["phase.task"]["n"] == n_tasks


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------

def test_heartbeats_written_and_ignored_by_reader(tmp_path):
    g = _tf_small()
    cands = _grid()
    ck = tmp_path / "hb.jsonl"
    with ExplorationEngine({"TF": g}, _cfg(), checkpoint=ck,
                           hb_every=0.0) as eng:
        pts = eng.run(cands)
    lines = [json.loads(x) for x in ck.read_text().splitlines()]
    hbs = [x["_hb"] for x in lines if "_hb" in x]
    recs = [x for x in lines if "_key" in x]
    assert hbs, "hb_every=0 should heartbeat after every record"
    assert len(recs) == len(pts)
    last = hbs[-1]
    assert last["done"] == last["total"] == len(pts)
    assert last["shard"] == "0/1"
    assert last["wall_s"] >= 0 and last["t"] > 0
    # the record parser skips heartbeat lines
    sweep = ResumableSweep.read(ck)
    assert len(sweep) == len(pts)


def test_heartbeats_survive_resume_and_merge(tmp_path):
    g = _tf_small()
    cands = _grid()
    cfg = _cfg()
    shards = []
    for i in range(2):
        ck = tmp_path / f"s{i}.jsonl"
        with ExplorationEngine({"TF": g}, cfg, checkpoint=ck,
                               hb_every=0.0) as eng:
            eng.run(cands, shard=(i, 2))
        shards.append(ck)
        n_rec, hb = parse_heartbeats(ck)
        assert hb is not None and hb["done"] == n_rec
    # resume on top of a heartbeat-bearing checkpoint: all tasks skip
    with ExplorationEngine({"TF": g}, cfg, checkpoint=shards[0],
                           hb_every=0.0) as eng:
        eng.run(cands, shard=(0, 2))
    # merge drops heartbeat lines but keeps every record
    merged = tmp_path / "merged.jsonl"
    merge_checkpoints(shards, merged)
    mlines = [json.loads(x) for x in merged.read_text().splitlines()]
    assert not any("_hb" in x for x in mlines)
    assert len([x for x in mlines if "_key" in x]) == \
        sum(parse_heartbeats(s)[0] for s in shards)
    full = run_dse(cands, {"TF": g}, cfg)
    resumed = run_dse(cands, {"TF": g}, cfg, checkpoint=merged)
    assert _sig(full) == _sig(resumed)


def test_shard_progress_rows(tmp_path):
    ck = tmp_path / "p.jsonl"
    ck.write_text(
        json.dumps({"_config": "x"}) + "\n" +
        json.dumps({"_key": "a", "e": 1}) + "\n" +
        json.dumps({"_hb": {"shard": "1/4", "done": 1, "total": 3,
                            "wall_s": 2.5, "t": 100.0}}) + "\n")
    rows = shard_progress([ck], now=110.0)
    assert rows == [{"shard": "1/4", "records": 1, "done": 1, "total": 3,
                     "wall_s": 2.5, "hb_age_s": 10.0}]


# ---------------------------------------------------------------------------
# Report golden output
# ---------------------------------------------------------------------------

def test_obs_report_golden():
    got = render_report(run=DATA / "run",
                        ckpts=[DATA / "shard0.jsonl"],
                        top=5, now=1786177000.0)
    want = (DATA / "report.txt").read_text()
    assert got == want


def test_obs_report_cli(capsys):
    from repro.launch.obs_report import main
    rc = main(["--run", str(DATA / "run"),
               "--ckpt", str(DATA / "shard0.jsonl")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "== run manifest ==" in out
    assert "== shard progress ==" in out
    assert "== Pareto snapshot" in out


def test_obs_report_empty_inputs(tmp_path):
    txt = render_report(run=tmp_path)
    assert "no obs artifacts" in txt


# ---------------------------------------------------------------------------
# vlog verbosity + provenance
# ---------------------------------------------------------------------------

def test_vlog_verbosity_gating(capsys, obs_dir):
    obs.vlog("sweep", "visible", level=1)
    obs.vlog("sweep", "hidden", level=2)
    out = capsys.readouterr().out
    assert "[sweep] visible" in out
    assert "hidden" not in out
    obs.set_verbosity(2)
    try:
        obs.vlog("sweep", "now-visible", level=2)
        obs.vlog("sweep", "kwarg-hidden", level=2, verbosity=0)
    finally:
        obs.set_verbosity(1)
    out = capsys.readouterr().out
    assert "now-visible" in out
    assert "kwarg-hidden" not in out
    obs.flush()
    logs = []
    for tf in Path(obs_dir).glob("trace-*.jsonl"):
        for line in tf.read_text().splitlines():
            ev = json.loads(line)
            if ev.get("ev") == "log":
                logs.append(ev["msg"])
    # every vlog call lands in the trace, printed or not
    for msg in ("visible", "hidden", "now-visible", "kwarg-hidden"):
        assert msg in logs


def test_git_head_env_override(monkeypatch):
    monkeypatch.setenv(GIT_COMMIT_ENV, "cafef00d")
    assert git_head() == "cafef00d"
    monkeypatch.delenv(GIT_COMMIT_ENV)
    head = git_head(Path(__file__).resolve().parents[1])
    assert head and head != "unknown"


def test_bench_git_head_delegates(monkeypatch):
    import importlib
    run_mod = importlib.import_module("benchmarks.run")
    monkeypatch.setenv(GIT_COMMIT_ENV, "beadfeed")
    assert run_mod._git_head(Path(".")) == "beadfeed"


def test_manifest_write_noop_when_disabled(tmp_path):
    assert not obs.enabled()
    assert obs.manifest.write_manifest({"stage": "x"}) is None
    p = obs.manifest.write_manifest({"stage": "x"}, directory=tmp_path)
    assert p is not None and json.loads(p.read_text())["stage"] == "x"
