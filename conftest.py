"""Pytest bootstrap: make ``src/`` importable and install compat shims.

Running via the tier-1 command (``PYTHONPATH=src python -m pytest``) already
loads ``src/sitecustomize.py`` at interpreter startup; this conftest makes a
bare ``pytest`` invocation equivalent — it prepends ``src`` to ``sys.path``
and installs the same hooks (idempotent):

  * the lazy ``jax.shard_map`` compat alias (``repro.compat``), and
  * the fallback finder serving vendored stand-ins for missing optional
    dependencies (e.g. ``hypothesis`` -> ``repro._vendor.minihypothesis``).

The uniquely named ``_repro_bootstrap`` is imported (rather than
``sitecustomize``) so this works even on Pythons whose distribution ships
its own ``sitecustomize`` module, which would already occupy the name in
``sys.modules`` and make the import a silent no-op.
"""

import os
import sys

SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

import _repro_bootstrap  # noqa: E402

_repro_bootstrap.install()
