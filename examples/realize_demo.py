import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # append: a pre-existing XLA_FLAGS must not swallow the device count
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

"""Close the co-exploration loop on CPU: DSE checkpoint -> MeshPlan ->
compiled sharded JAX program (interpret-mode Pallas) -> measured-vs-
predicted report -> Tech overlay -> measured-calibrated second DSE pass.

The two env lines above must stay first (jax locks the device count on
first init).  Everything runs in ~a minute on a laptop CPU:

  PYTHONPATH=src python examples/realize_demo.py
"""

import time

from repro.core.dse import DSEConfig, run_dse
from repro.core.hw import ArchConfig
from repro.core.sa import SAConfig
from repro.core.workloads import transformer

CKPT = "results/realize_demo.ckpt.jsonl"
OUT = "results/realize.jsonl"


def main() -> None:
    # -- 1. a tiny keep_mappings DSE: 2 candidates, 4 cores each ----------
    g = transformer(n_layers=1, d_model=64, d_ff=128, seq=32, name="tf-demo")
    cands = [
        ArchConfig(x_cores=2, y_cores=2, xcut=1, ycut=1, noc_bw=32,
                   d2d_bw=16, dram_bw=64, glb_kb=512, macs_per_core=1024),
        ArchConfig(x_cores=2, y_cores=2, xcut=2, ycut=1, noc_bw=32,
                   d2d_bw=16, dram_bw=64, glb_kb=512, macs_per_core=1024),
    ]
    cfg = DSEConfig(batch=4, sa=SAConfig(iters=120, seed=0),
                    keep_mappings=True)
    os.makedirs("results", exist_ok=True)
    for p in (CKPT, OUT):
        if os.path.exists(p):
            os.unlink(p)                  # demo measures from scratch
    t0 = time.time()
    baseline = run_dse(cands, {"TF": g}, cfg, checkpoint=CKPT)
    print(f"[demo] DSE over {len(cands)} candidates "
          f"({time.time() - t0:.1f}s); best {baseline[0].arch.label()}")

    # -- 2. realize: checkpoint -> plans -> compiled sharded programs -----
    import jax
    from repro.core.explore import ResumableSweep
    from repro.realize.calibrate import (calibrated_candidates, fit_overlay,
                                         TechOverlay)
    from repro.realize.measure import measure_candidate
    from repro.realize.plan import load_realize_candidates, plans_for
    from repro.realize.program import build_program

    pool = list(jax.devices())
    rcands = load_realize_candidates(CKPT, {"TF": g}, top=2)
    sweep = ResumableSweep(OUT, "realize-demo:v1")
    reports = []
    for cand, plan in plans_for(rcands, len(pool)):
        t0 = time.time()
        prog = build_program(cand.graph, plan, devices=pool)
        prog.compile_all()
        rep = measure_candidate(cand, prog, execute=True)
        reports.append(rep)
        sweep.add(cand.key, rep.to_record())
        tot = rep.totals()
        print(f"[demo] realized {cand.arch.label()}: "
              f"{len(plan.stages)} stages on "
              f"{plan.n_devices_needed} devices "
              f"({time.time() - t0:.1f}s, wall {tot['wall_s']*1e3:.0f}ms); "
              f"measured/predicted geomean: "
              + "  ".join(f"{k}={v:.3g}"
                          for k, v in sorted(rep.ratio_summary().items())))

    # -- 3. calibrate + second pass ---------------------------------------
    overlay = fit_overlay(reports, source="realize_demo")
    print(f"[demo] Tech overlay: f_d2d={overlay.f_d2d:.3g} "
          f"f_noc={overlay.f_noc:.3g} f_dram={overlay.f_dram:.3g} "
          f"(evidence: {overlay.n_stages} stages)")

    identity = TechOverlay()
    same = run_dse(calibrated_candidates(cands, identity), {"TF": g}, cfg)
    assert [p.objective for p in same] == \
        [p.objective for p in baseline], "identity overlay changed the DSE!"
    print("[demo] identity overlay: second pass bit-identical to baseline "
          "(calibration off => no behavior change)")

    cal = run_dse(calibrated_candidates(cands, overlay), {"TF": g}, cfg)
    # both lists are sorted by their own objective: pair rows by arch
    # label or a re-ranking would mis-attribute the calibrated numbers
    cal_by_label = {p.arch.label(): p.objective for p in cal}
    print(f"{'arch':42s} {'baseline obj':>14s} {'calibrated obj':>15s}")
    for b in baseline:
        print(f"{b.arch.label():42s} {b.objective:14.4e} "
              f"{cal_by_label[b.arch.label()]:15.4e}")
    flip = ([p.arch.label() for p in baseline]
            != [p.arch.label() for p in cal])
    print(f"[demo] measured-calibrated costs "
          f"{'re-ranked the candidates' if flip else 'kept the ranking'}; "
          f"report -> {OUT}")


if __name__ == "__main__":
    main()
