"""End-to-end driver: train the ~135M-param smollm-135m for a few hundred
steps on the synthetic packed-LM pipeline, with checkpointing and the
straggler watchdog.  This is the full-size assigned config (NOT reduced) at
a CPU-sized batch; on a pod the identical Trainer runs under the production
mesh.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(A full-size 135M CPU step takes a while; --small trains a 4-layer variant
for CI-speed demonstration.)
"""

import argparse

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--small", action="store_true",
                    help="4-layer variant (fast demo)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if args.small:
        cfg = cfg.replace(n_layers=4, remat=False)
        args.seq = min(args.seq, 128)
    print(f"[train_lm] {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps x batch {args.batch} x seq {args.seq}")

    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    tcfg = TrainConfig(
        steps=args.steps, ckpt_every=max(20, args.steps // 5),
        ckpt_dir=args.ckpt_dir, log_every=10,
        opt=AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10,
                        total_steps=args.steps))
    out = Trainer(cfg, data, tcfg).run(resume=True)
    first = sum(out["losses"][:10]) / max(1, len(out["losses"][:10]))
    last = sum(out["losses"][-10:]) / max(1, len(out["losses"][-10:]))
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} over "
          f"{len(out['losses'])} steps; straggler events: "
          f"{out['slow_steps']}")


if __name__ == "__main__":
    main()
