"""Batched serving example: wave-based continuous batching of mixed-length
requests against a reduced qwen3-0.6b (qk-norm GQA decoder).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model_api
from repro.runtime.serve_loop import Request, Server


def main() -> None:
    cfg = get_config("qwen3-0.6b").reduced()
    api = model_api(cfg)
    params, _ = api.init_params(jax.random.PRNGKey(0))
    srv = Server(cfg, params, max_batch=4, max_seq=256)

    rng = np.random.default_rng(0)
    n_req = 10
    t0 = time.time()
    for i in range(n_req):
        srv.submit(Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab,
                                size=int(rng.integers(4, 48))).astype(np.int32),
            max_new=24))
    results = srv.run_until_empty()
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests -> {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s on CPU)")
    for r in results[:5]:
        print(f"  rid={r.rid:2d} new_tokens={len(r.tokens):3d} "
              f"head={r.tokens[:8].tolist()}")


if __name__ == "__main__":
    main()
