"""Gemini-mapped pipelined serving: the paper's technique driving a real
JAX execution.

The LM architecture's layer DAG is exported to the Gemini IR, the SA engine
explores stage placement against an abstract accelerator mirroring the mesh
(chips=cores, pods=chiplets, ICI=NoC, DCI=D2D), and the resulting MeshPlan
executes a pipelined forward pass with measured per-stage times.

Run:  PYTHONPATH=src python examples/map_to_mesh.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.bridge import mesh_as_arch, plan_for_graph
from repro.core.workloads.lm_graph import lm_graph
from repro.models import lm, model_api
from repro.runtime.pipeline import PipelineExec


def main() -> None:
    cfg = get_config("smollm-135m").reduced().replace(n_layers=8)
    seq, batch = 64, 4
    g = lm_graph(cfg, seq=seq)
    print(f"[map] exported {cfg.name} -> {len(g.layers)} Gemini layers")

    # abstract accelerator mirroring a 2x2 chip mesh (1 'pod')
    arch = mesh_as_arch(x_chips=2, y_chips=2, pods_x=1)
    t0 = time.time()
    plan = plan_for_graph(g, arch, total_batch=batch, sa_iters=600)
    print(f"[map] Gemini SA produced {len(plan.stages)} stages in "
          f"{time.time() - t0:.1f}s "
          f"(modelled delay {plan.cost_delay_s * 1e3:.2f} ms, "
          f"energy {plan.cost_energy_j * 1e3:.2f} mJ)")
    for i, st in enumerate(plan.stages):
        print(f"  stage {i}: {len(st.layers):2d} layers on devices "
              f"{st.devices[:8]}{'...' if len(st.devices) > 8 else ''}")

    api = model_api(cfg)
    params, _ = api.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                              cfg.vocab)
    pipe = PipelineExec(cfg=cfg, params=params, plan=plan)
    logits = pipe.forward(toks, n_micro=2)
    logits.block_until_ready()
    print(f"[map] pipelined logits {logits.shape}; per-stage seconds: "
          f"{[round(t, 3) for t in pipe.stage_times]}")

    expected, _, _ = lm.forward(cfg, params, {"tokens": toks}, mode="train")
    err = float(jax.numpy.abs(logits - expected).max())
    print(f"[map] max |pipelined - monolithic| = {err:.2e}  "
          f"({'OK' if err < 0.05 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
