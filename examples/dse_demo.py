"""Mini architecture DSE (paper Table I flavor, trimmed for one CPU core):
co-explore chiplet cut / NoC bandwidth / GLB size for a 72-TOPS budget on
the Transformer workload and print the Pareto view.

Run:  PYTHONPATH=src python examples/dse_demo.py
"""

from repro.core.dse import DSEConfig, grid_candidates, run_dse
from repro.core.sa import SAConfig
from repro.core.workloads import transformer


def main() -> None:
    cands = grid_candidates(
        72.0, mac_options=(1024,), cut_options=(1, 2, 6),
        dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
        glb_options=(1024, 2048))
    print(f"[dse] exploring {len(cands)} candidates "
          f"(trimmed grid; full grid in benchmarks/table1_dse.py)")
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=800, seed=0))
    pts = run_dse(cands, {"TF": transformer()}, cfg, use_sa=True,
                  progress=True)
    print(f"\n{'rank':4s} {'architecture':46s} {'MC$':>7s} "
          f"{'E(mJ)':>8s} {'D(ms)':>8s} {'MC*E*D':>10s}")
    for i, p in enumerate(pts):
        print(f"{i + 1:4d} {p.arch.label():46s} {p.mc:7.1f} "
              f"{p.energy_j * 1e3:8.2f} {p.delay_s * 1e3:8.3f} "
              f"{p.objective:10.3e}")
    best = pts[0]
    print(f"\n[dse] best: {best.arch.label()}  "
          f"(paper's 72-TOPS optimum was (2, 36, 144GB/s, 32GB/s, 16GB/s, "
          f"2MB, 1024))")


if __name__ == "__main__":
    main()
