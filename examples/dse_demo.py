"""Mini architecture DSE (paper Table I flavor, trimmed for small machines):
co-explore chiplet cut / NoC bandwidth / GLB size for a 72-TOPS budget on
the Transformer workload, through the exploration engine — T-Map screening,
parallel workers, a resumable checkpoint and the (MC, E, D) Pareto frontier.

Run:  PYTHONPATH=src python examples/dse_demo.py
Kill it mid-sweep and re-run: completed candidates are skipped
(results/dse_demo.ckpt.jsonl).
"""

import os

from repro.core.dse import DSEConfig, grid_candidates, run_dse
from repro.core.explore import pareto_frontier
from repro.core.sa import SAConfig
from repro.core.workloads import transformer


def main() -> None:
    cands = grid_candidates(
        72.0, mac_options=(1024,), cut_options=(1, 2, 6),
        dram_per_tops=(2.0,), noc_options=(16, 32), d2d_ratio=(0.5,),
        glb_options=(1024, 2048))
    n_workers = max(1, min(4, os.cpu_count() or 1))
    print(f"[dse] exploring {len(cands)} candidates with {n_workers} "
          f"workers (trimmed grid; full grid in benchmarks/table1_dse.py)")
    cfg = DSEConfig(batch=64, sa=SAConfig(iters=800, seed=0))
    os.makedirs("results", exist_ok=True)
    # screening: every candidate gets the cheap T-Map score, the best 2/3
    # get the full SA refinement; screen_keep=1.0 would skip the screen
    pts = run_dse(cands, {"TF": transformer()}, cfg, use_sa=True,
                  progress=True, n_workers=n_workers, screen_keep=0.67,
                  checkpoint="results/dse_demo.ckpt.jsonl")
    print(f"\n{'rank':4s} {'architecture':46s} {'MC$':>7s} "
          f"{'E(mJ)':>8s} {'D(ms)':>8s} {'MC*E*D':>10s}")
    for i, p in enumerate(pts):
        print(f"{i + 1:4d} {p.arch.label():46s} {p.mc:7.1f} "
              f"{p.energy_j * 1e3:8.2f} {p.delay_s * 1e3:8.3f} "
              f"{p.objective:10.3e}")
    frontier = pareto_frontier(pts)
    print(f"\n[dse] (MC, E, D) Pareto frontier "
          f"({len(frontier)}/{len(pts)} refined points are non-dominated):")
    for p in frontier:
        print(f"  {p.arch.label():46s} MC=${p.mc:.1f} "
              f"E={p.energy_j * 1e3:.2f}mJ D={p.delay_s * 1e3:.3f}ms")
    best = pts[0]
    print(f"\n[dse] best: {best.arch.label()}  "
          f"(paper's 72-TOPS optimum was (2, 36, 144GB/s, 32GB/s, 16GB/s, "
          f"2MB, 1024))")


if __name__ == "__main__":
    main()
