"""Quickstart: the whole Gemini flow in one minute on CPU.

1. Build the paper's Transformer workload DAG.
2. Evaluate the Tangram stripe baseline (T-Map) on the Simba architecture.
3. Run the SA mapping engine (G-Map) and show the gains + D2D reduction.
4. Price both architectures with the Monetary-Cost evaluator.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.analyzer import d2d_hop_stats
from repro.core.evaluator import Evaluator
from repro.core.graph_partition import partition_graph
from repro.core.hw import gemini_arch_72t, simba_arch
from repro.core.mc import evaluate_mc
from repro.core.sa import SAConfig, sa_optimize
from repro.core.tangram import tangram_map
from repro.core.workloads import transformer


def main() -> None:
    g = transformer(n_layers=3, d_model=512, d_ff=2048, seq=512)
    batch = 64

    for arch, name in ((simba_arch(), "S-Arch (Simba)"),
                       (gemini_arch_72t(), "G-Arch (paper DSE)")):
        print(f"\n=== {name}: {arch.label()} | {arch.tops:.0f} TOPS ===")
        mc = evaluate_mc(arch)
        print(f"monetary cost: ${mc.total:.1f}  (silicon ${mc.silicon:.1f}, "
              f"dram ${mc.dram:.1f}, packaging ${mc.packaging:.1f}; "
              f"D2D area share {mc.d2d_area_fraction:.0%})")

        groups = partition_graph(g, arch, batch)
        print(f"graph partition: {len(groups)} layer groups, "
              f"batch units {[gr.batch_unit for gr in groups]}")

        ev = Evaluator(arch, g)
        tmap = tangram_map(groups, g, arch)
        base = ev.evaluate(tmap, batch)
        print(f"T-Map baseline: delay {base.delay_s * 1e3:.2f} ms, "
              f"energy {base.energy_j * 1e3:.1f} mJ")

        res = sa_optimize(g, arch, groups, batch,
                          SAConfig(iters=2000, seed=0), init=tmap,
                          evaluator=ev)
        print(f"G-Map (SA):     delay {res.delay_s * 1e3:.2f} ms "
              f"({base.delay_s / res.delay_s:.2f}x), "
              f"energy {res.energy_j * 1e3:.1f} mJ "
              f"({base.energy_j / res.energy_j:.2f}x)")

        st = d2d_hop_stats(arch, ev.evaluate(tmap, batch).analyses)
        sg = d2d_hop_stats(arch, ev.evaluate(res.mapping, batch).analyses)
        print(f"D2D hop-bytes: {st['d2d_hop_bytes']:.2e} -> "
              f"{sg['d2d_hop_bytes']:.2e} "
              f"({100 * (1 - sg['d2d_hop_bytes'] / max(st['d2d_hop_bytes'], 1e-12)):+.0f}%)")


if __name__ == "__main__":
    main()
